//! Canonical `.bcmd` encoding: a little-endian binary form (the file
//! format) plus a JSON dump for human inspection.
//!
//! The binary layout for version 1, in order:
//!
//! ```text
//! magic "BCMD" · u32 version
//! header: u32 n_total · u32 n_hist · u32 h · u32 k
//!         f64 freq · f64 alpha · f64 lambda
//!         u32 m_chunk · u8 fill_missing
//!         u32 freq32 bits · u32 lambda32 bits
//!         u32 t_len · t_len × u32 (f32 bits)
//! slots:  u32 count · per slot: str name · u8 dtype (0=f32, 1=i32)
//!         · u32 rank · rank × u32
//! jobs:   u32 count · per job: str tag · u32 m
//!         · u32 width (0 = absent) · u32 height (0 = absent)
//! ops:    u32 count · per op: u8 opcode · u32 job · u32 chunk
//!         · stage_gather (0): u32 start · u32 width
//!           · u32 nvals · nvals × u32 (f32 bits)
//!         · readback (5): u32 start · u32 width
//! ```
//!
//! `str` is `u32 len` + UTF-8 bytes. Floats are stored as raw IEEE
//! bits so NaN payloads survive the round trip and
//! `encode(decode(bytes)) == bytes` holds for every accepted stream.
//! The slot table is redundant (derivable from the header) but is
//! written and **checked** on decode: a stream whose slots disagree
//! with the v1 contract is rejected before any op could execute.

use crate::b64::base64_encode;
use crate::error::{bail, ensure, Result};
use crate::json::Value;
use crate::runtime::Dtype;

use super::{slot_table, CmdStream, JobDesc, Op, StreamHeader, BCMD_MAGIC, BCMD_VERSION};

const OP_STAGE_GATHER: u8 = 0;
const OP_FILL_COLUMNS: u8 = 1;
const OP_BATCHED_FIT: u8 = 2;
const OP_MOSUM: u8 = 3;
const OP_DETECT_BREAKS: u8 = 4;
const OP_READBACK: u8 = 5;

fn dtype_code(d: Dtype) -> u8 {
    match d {
        Dtype::F32 => 0,
        Dtype::I32 => 1,
    }
}

fn dtype_name(d: Dtype) -> &'static str {
    match d {
        Dtype::F32 => "f32",
        Dtype::I32 => "i32",
    }
}

struct Wr {
    b: Vec<u8>,
}

impl Wr {
    fn u8(&mut self, v: u8) {
        self.b.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }
    fn usize32(&mut self, v: usize) {
        self.u32(v as u32);
    }
    fn f64(&mut self, v: f64) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }
    fn f32bits(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.usize32(s.len());
        self.b.extend_from_slice(s.as_bytes());
    }
}

struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let left = self.b.len() - self.pos;
        ensure!(
            left >= n,
            "truncated .bcmd: wanted {n} bytes at offset {}, {left} available",
            self.pos
        );
        let out = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn len32(&mut self) -> Result<usize> {
        Ok(self.u32()? as usize)
    }
    fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(f64::from_le_bytes(a))
    }
    fn f32bits(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    /// Read `n` f32s without trusting `n` for an up-front allocation:
    /// the byte length is checked first, so a hostile count fails as a
    /// truncation error instead of an OOM.
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }
    fn str(&mut self) -> Result<String> {
        let n = self.len32()?;
        let bytes = self.take(n)?;
        match std::str::from_utf8(bytes) {
            Ok(s) => Ok(s.to_string()),
            Err(_) => bail!("invalid UTF-8 string at offset {}", self.pos - n),
        }
    }
}

impl CmdStream {
    /// Serialise to the canonical `.bcmd` binary form.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Wr { b: Vec::new() };
        w.b.extend_from_slice(&BCMD_MAGIC);
        w.u32(BCMD_VERSION);

        let h = &self.header;
        w.usize32(h.n_total);
        w.usize32(h.n_hist);
        w.usize32(h.h);
        w.usize32(h.k);
        w.f64(h.freq);
        w.f64(h.alpha);
        w.f64(h.lambda);
        w.usize32(h.m_chunk);
        w.u8(h.fill_missing as u8);
        w.f32bits(h.freq32);
        w.f32bits(h.lambda32);
        w.usize32(h.t_axis.len());
        for &t in &h.t_axis {
            w.f32bits(t);
        }

        let slots = self.slot_table();
        w.usize32(slots.len());
        for s in &slots {
            w.str(&s.name);
            w.u8(dtype_code(s.dtype));
            w.usize32(s.shape.len());
            for &d in &s.shape {
                w.usize32(d);
            }
        }

        w.usize32(self.jobs.len());
        for j in &self.jobs {
            w.str(&j.tag);
            w.usize32(j.m);
            w.usize32(j.width.unwrap_or(0));
            w.usize32(j.height.unwrap_or(0));
        }

        w.usize32(self.ops.len());
        for op in &self.ops {
            match op {
                Op::StageGather { job, chunk, start, width, data } => {
                    w.u8(OP_STAGE_GATHER);
                    w.u32(*job);
                    w.u32(*chunk);
                    w.u32(*start);
                    w.u32(*width);
                    w.usize32(data.len());
                    for &v in data {
                        w.f32bits(v);
                    }
                }
                Op::FillColumns { job, chunk } => {
                    w.u8(OP_FILL_COLUMNS);
                    w.u32(*job);
                    w.u32(*chunk);
                }
                Op::BatchedFit { job, chunk } => {
                    w.u8(OP_BATCHED_FIT);
                    w.u32(*job);
                    w.u32(*chunk);
                }
                Op::Mosum { job, chunk } => {
                    w.u8(OP_MOSUM);
                    w.u32(*job);
                    w.u32(*chunk);
                }
                Op::DetectBreaks { job, chunk } => {
                    w.u8(OP_DETECT_BREAKS);
                    w.u32(*job);
                    w.u32(*chunk);
                }
                Op::Readback { job, chunk, start, width } => {
                    w.u8(OP_READBACK);
                    w.u32(*job);
                    w.u32(*chunk);
                    w.u32(*start);
                    w.u32(*width);
                }
            }
        }
        w.b
    }

    /// Parse and validate a `.bcmd` binary stream. Fails closed: bad
    /// magic, unknown versions, truncation, trailing bytes, a slot
    /// table that disagrees with the v1 contract, and structurally
    /// invalid ops are all hard errors.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let mut r = Rd { b: bytes, pos: 0 };
        let magic = r.take(4)?;
        ensure!(magic == BCMD_MAGIC, "not a .bcmd command stream (bad magic)");
        let version = r.u32()?;
        ensure!(
            version == BCMD_VERSION,
            "unsupported .bcmd version {version} (this build speaks v{BCMD_VERSION})"
        );

        let n_total = r.len32()?;
        let n_hist = r.len32()?;
        let h = r.len32()?;
        let k = r.len32()?;
        let freq = r.f64()?;
        let alpha = r.f64()?;
        let lambda = r.f64()?;
        let m_chunk = r.len32()?;
        let fill_missing = match r.u8()? {
            0 => false,
            1 => true,
            other => bail!("fill_missing flag must be 0 or 1, got {other}"),
        };
        let freq32 = r.f32bits()?;
        let lambda32 = r.f32bits()?;
        let t_len = r.len32()?;
        let t_axis = r.f32s(t_len)?;
        let header = StreamHeader {
            n_total,
            n_hist,
            h,
            k,
            freq,
            alpha,
            lambda,
            m_chunk,
            fill_missing,
            t_axis,
            freq32,
            lambda32,
        };

        let want_slots = slot_table(&header);
        let n_slots = r.len32()?;
        ensure!(
            n_slots == want_slots.len(),
            "slot table has {n_slots} entries, the v1 chunk contract has {}",
            want_slots.len()
        );
        for want in &want_slots {
            let name = r.str()?;
            let dtype = r.u8()?;
            let rank = r.len32()?;
            let mut shape = Vec::new();
            for _ in 0..rank {
                shape.push(r.len32()?);
            }
            ensure!(
                name == want.name && dtype == dtype_code(want.dtype) && shape == want.shape,
                "slot {name:?} does not match the v1 chunk contract"
            );
        }

        let n_jobs = r.len32()?;
        let mut jobs = Vec::new();
        for _ in 0..n_jobs {
            let tag = r.str()?;
            let m = r.len32()?;
            let width = match r.len32()? {
                0 => None,
                w => Some(w),
            };
            let height = match r.len32()? {
                0 => None,
                h => Some(h),
            };
            jobs.push(JobDesc { tag, m, width, height });
        }

        let n_ops = r.len32()?;
        let chunk_len = header.n_total * header.m_chunk;
        let mut ops = Vec::new();
        for i in 0..n_ops {
            let code = r.u8()?;
            let job = r.u32()?;
            let chunk = r.u32()?;
            let op = match code {
                OP_STAGE_GATHER => {
                    let start = r.u32()?;
                    let width = r.u32()?;
                    let nvals = r.len32()?;
                    ensure!(
                        nvals == chunk_len,
                        "op {i} (stage_gather) declares {nvals} values, slot y holds {chunk_len}"
                    );
                    let data = r.f32s(nvals)?;
                    Op::StageGather { job, chunk, start, width, data }
                }
                OP_FILL_COLUMNS => Op::FillColumns { job, chunk },
                OP_BATCHED_FIT => Op::BatchedFit { job, chunk },
                OP_MOSUM => Op::Mosum { job, chunk },
                OP_DETECT_BREAKS => Op::DetectBreaks { job, chunk },
                OP_READBACK => {
                    let start = r.u32()?;
                    let width = r.u32()?;
                    Op::Readback { job, chunk, start, width }
                }
                other => bail!("unknown opcode {other} at op {i}"),
            };
            ops.push(op);
        }

        ensure!(
            r.pos == bytes.len(),
            "{} trailing bytes after the op list",
            bytes.len() - r.pos
        );
        let stream = CmdStream { header, jobs, ops };
        stream.validate()?;
        Ok(stream)
    }

    /// JSON view of the stream for inspection (`bfast replay --dump`).
    /// Gather payloads are base64 of the little-endian f32 bytes so
    /// NaN samples stay representable and the document stays valid
    /// JSON; `values` carries the element count for quick reading.
    pub fn to_json(&self) -> Value {
        let h = &self.header;
        let header = Value::obj(vec![
            ("n_total", Value::Num(h.n_total as f64)),
            ("n_hist", Value::Num(h.n_hist as f64)),
            ("h", Value::Num(h.h as f64)),
            ("k", Value::Num(h.k as f64)),
            ("freq", Value::Num(h.freq)),
            ("alpha", Value::Num(h.alpha)),
            ("lambda", Value::Num(h.lambda)),
            ("m_chunk", Value::Num(h.m_chunk as f64)),
            ("fill_missing", Value::Bool(h.fill_missing)),
            ("freq_f32", Value::Num(h.freq32 as f64)),
            ("lambda_f32", Value::Num(h.lambda32 as f64)),
            (
                "t_axis",
                Value::Arr(h.t_axis.iter().map(|&t| Value::Num(t as f64)).collect()),
            ),
        ]);
        let slots = Value::Arr(
            self.slot_table()
                .iter()
                .map(|s| {
                    Value::obj(vec![
                        ("name", Value::Str(s.name.clone())),
                        ("dtype", Value::Str(dtype_name(s.dtype).to_string())),
                        ("shape", Value::arr_usize(&s.shape)),
                    ])
                })
                .collect(),
        );
        let jobs = Value::Arr(
            self.jobs
                .iter()
                .map(|j| {
                    let dim = |d: Option<usize>| match d {
                        Some(v) => Value::Num(v as f64),
                        None => Value::Null,
                    };
                    Value::obj(vec![
                        ("tag", Value::Str(j.tag.clone())),
                        ("m", Value::Num(j.m as f64)),
                        ("width", dim(j.width)),
                        ("height", dim(j.height)),
                    ])
                })
                .collect(),
        );
        let ops = Value::Arr(self.ops.iter().map(op_to_json).collect());
        Value::obj(vec![
            ("v", Value::Num(BCMD_VERSION as f64)),
            ("header", header),
            ("slots", slots),
            ("jobs", jobs),
            ("ops", ops),
        ])
    }
}

fn op_to_json(op: &Op) -> Value {
    let mut fields = vec![
        ("op", Value::Str(op.name().to_string())),
        ("job", Value::Num(op.job() as f64)),
        ("chunk", Value::Num(op.chunk() as f64)),
    ];
    match op {
        Op::StageGather { start, width, data, .. } => {
            let mut bytes = Vec::with_capacity(data.len() * 4);
            for &v in data {
                bytes.extend_from_slice(&v.to_bits().to_le_bytes());
            }
            fields.push(("start", Value::Num(*start as f64)));
            fields.push(("width", Value::Num(*width as f64)));
            fields.push(("values", Value::Num(data.len() as f64)));
            fields.push(("data_b64", Value::Str(base64_encode(&bytes))));
        }
        Op::Readback { start, width, .. } => {
            fields.push(("start", Value::Num(*start as f64)));
            fields.push(("width", Value::Num(*width as f64)));
        }
        _ => {}
    }
    Value::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::super::{record_stream, RecordJob};
    use super::*;
    use crate::params::BfastParams;
    use crate::synth::ArtificialDataset;

    fn params() -> BfastParams {
        BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap()
    }

    fn stream() -> CmdStream {
        let p = params();
        let gen = ArtificialDataset::new(p.clone(), 25, 11).generate();
        let mut stack = gen.stack;
        // NaN payloads must survive the byte round trip
        stack.data_mut()[3] = f32::NAN;
        record_stream(&[RecordJob { tag: "t".into(), stack: &stack, params: &p }], 10, true)
            .unwrap()
    }

    #[test]
    fn encode_decode_encode_is_a_fixed_point() {
        let s = stream();
        let bytes = s.encode();
        let back = CmdStream::decode(&bytes).unwrap();
        assert_eq!(back.encode(), bytes);
        assert_eq!(back.jobs, s.jobs);
        assert_eq!(back.ops.len(), s.ops.len());
        // spot-check the NaN travelled as its exact bit pattern
        match (&s.ops[0], &back.ops[0]) {
            (Op::StageGather { data: a, .. }, Op::StageGather { data: b, .. }) => {
                assert_eq!(a[3].to_bits(), b[3].to_bits());
                assert!(b[3].is_nan());
            }
            other => panic!("first ops should be gathers, got {other:?}"),
        }
    }

    #[test]
    fn every_truncation_is_rejected() {
        let bytes = stream().encode();
        for n in 0..bytes.len() {
            let err = CmdStream::decode(&bytes[..n]).unwrap_err().to_string();
            assert!(!err.is_empty(), "truncation at {n} must error");
        }
    }

    #[test]
    fn corrupt_streams_fail_closed() {
        let bytes = stream().encode();

        let mut bad = bytes.clone();
        bad[0] = b'X';
        let err = CmdStream::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        let mut bad = bytes.clone();
        bad[4] = 2; // version 2
        let err = CmdStream::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("unsupported .bcmd version 2"), "{err}");

        let mut bad = bytes.clone();
        bad.push(0);
        let err = CmdStream::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");

        // flipping a slot-table dimension breaks the contract check;
        // the first dim of slot "y" sits at a computable offset:
        // magic+version (8), four u32 params (16), three f64 (24),
        // m_chunk (4), fill flag (1), two f32 bits (8), t_len (4),
        // the t axis, slot count (4), name "y" (4 + 1), dtype (1),
        // rank (4).
        let t = stream().header.t_axis.len();
        let dim0 = 8 + 16 + 24 + 4 + 1 + 8 + 4 + 4 * t + 4 + 5 + 1 + 4;
        let mut bad = bytes.clone();
        bad[dim0] ^= 0xff;
        let err = CmdStream::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("chunk contract"), "{err}");
    }

    #[test]
    fn json_dump_is_structurally_complete() {
        let s = stream();
        let v = s.to_json();
        let text = v.to_string_pretty();
        let parsed = crate::json::parse(&text).unwrap();
        assert_eq!(parsed.get("v").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            parsed.get("header").unwrap().get("m_chunk").unwrap().as_usize().unwrap(),
            10
        );
        assert_eq!(parsed.get("slots").unwrap().as_arr().unwrap().len(), 6);
        assert_eq!(
            parsed.get("ops").unwrap().as_arr().unwrap().len(),
            s.ops.len()
        );
        let first = &parsed.get("ops").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("op").unwrap().as_str().unwrap(), "stage_gather");
        assert_eq!(
            first.get("values").unwrap().as_usize().unwrap(),
            s.header.n_total * s.header.m_chunk
        );
    }
}
