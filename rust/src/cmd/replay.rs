//! Stream replay: parse a [`CmdStream`] and dispatch its ops to the
//! fused CPU kernels.
//!
//! [`ReplayExecutor`] is the interpreter. It keeps a **translation
//! cache** ([`ReplayState`], the analogue of the emulated backend's
//! prepared-engine cache): the expensive design-side preparation —
//! history design matrix, monitoring boundary, staging buffer — is
//! built once per chunk contract (shape + f32 time axis + freq + λ,
//! compared bitwise) and reused across every op, chunk, job, and
//! stream that shares it. Op dispatch then calls the same per-phase
//! entry points (`FusedCpuBfast::fit_residuals` / `mosum_strip` /
//! `detect_from_strip`) that the fused engine's own `run` is built
//! from, which is why replayed maps are bit-identical to a direct run.
//!
//! [`CmdBackend`] adapts record-then-replay to the coordinator's
//! `ExecutorBackend` seam (`--engine cmd`): each staged chunk is
//! recorded into a single-chunk stream and immediately replayed, so
//! the whole coordinator pipeline — staging, queueing, readback —
//! exercises the command-stream path end to end.

use super::{CmdStream, Op, Recorder, StreamHeader};
use crate::api::AnalysisResult;
use crate::cpu::FusedCpuBfast;
use crate::error::{bail, ensure, Context, Result};
use crate::fill;
use crate::metrics::PhaseTimes;
use crate::params::BfastParams;
use crate::raster::{BreakMap, TimeStack};
use crate::runtime::{
    ArtifactSpec, ChunkExecutor, ChunkOutput, Dtype, ExecutorBackend, TensorSpec,
    PHASE_FUSED, PHASE_READBACK, PHASE_TRANSFER,
};
use crate::threadpool;
use crate::trace;
use std::time::Duration;

/// Engine label stamped on results produced by offline replay.
pub const REPLAY_ENGINE: &str = "cmdstream";

/// Phase names for per-op time attribution during replay.
pub const OP_STAGE: &str = "stage gather";
pub const OP_FILL: &str = "fill columns";
pub const OP_FIT: &str = "batched fit";
pub const OP_MOSUM: &str = "mosum";
pub const OP_DETECT: &str = "detect breaks";
pub const OP_READBACK: &str = "readback";

/// The prepared-kernel cache: everything derivable from the stream
/// header, keyed on its exact f32 bits. Rebuilding only happens when
/// a stream with a different chunk contract arrives.
struct ReplayState {
    shape: (usize, usize, usize, usize, usize),
    t_bits: Vec<u32>,
    freq_bits: u32,
    lambda_bits: u32,
    engine: FusedCpuBfast,
    /// Reused staging buffer shaped (n_total, m_chunk) — slot `y`.
    stage: TimeStack,
}

/// Interprets command streams against the fused CPU kernels (see the
/// module docs). Reusable across streams; the translation cache
/// persists as long as the chunk contract does.
pub struct ReplayExecutor {
    threads: usize,
    state: Option<ReplayState>,
}

impl Default for ReplayExecutor {
    fn default() -> Self {
        Self::new()
    }
}

impl ReplayExecutor {
    pub fn new() -> Self {
        Self { threads: threadpool::default_threads(), state: None }
    }

    /// Override the compute thread count (≥ 1).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    fn ensure_state(&mut self, h: &StreamHeader) -> Result<()> {
        let shape = (h.n_total, h.n_hist, h.h, h.k, h.m_chunk);
        let fresh = match &self.state {
            Some(st) => {
                st.shape == shape
                    && st.freq_bits == h.freq32.to_bits()
                    && st.lambda_bits == h.lambda32.to_bits()
                    && st.t_bits.len() == h.t_axis.len()
                    && st.t_bits.iter().zip(&h.t_axis).all(|(b, t)| *b == t.to_bits())
            }
            None => false,
        };
        if fresh {
            return Ok(());
        }
        let t64: Vec<f64> = h.t_axis.iter().map(|&v| v as f64).collect();
        // Mirror the emulated backend exactly: the engine is built
        // from the f32 chunk-contract values, upcast — and α only
        // labels the analysis; the boundary is fully determined by
        // the λ shipped in the header.
        let params = BfastParams::with_lambda(
            h.n_total,
            h.n_hist,
            h.h,
            h.k,
            h.freq32 as f64,
            0.05,
            h.lambda32 as f64,
        )?;
        let engine = FusedCpuBfast::new(params, &t64)?.with_threads(self.threads);
        let stage = TimeStack::zeros(h.n_total, h.m_chunk)
            .with_time_axis(t64)
            .context("cmd replay: f32-rounded chunk time axis")?;
        self.state = Some(ReplayState {
            shape,
            t_bits: h.t_axis.iter().map(|t| t.to_bits()).collect(),
            freq_bits: h.freq32.to_bits(),
            lambda_bits: h.lambda32.to_bits(),
            engine,
            stage,
        });
        Ok(())
    }

    /// Execute every op in order; returns one break map per job (in
    /// job-table order). Ops execute under a trace span each, and
    /// their time lands in `times` under the [`OP_STAGE`]-family
    /// phase names. Out-of-sequence ops (a fit with nothing staged, a
    /// readback with nothing detected) are hard errors.
    pub fn execute(&mut self, stream: &CmdStream, times: &mut PhaseTimes) -> Result<Vec<BreakMap>> {
        stream.validate()?;
        self.ensure_state(&stream.header)?;
        let h = &stream.header;
        let (n_total, mc) = (h.n_total, h.m_chunk);
        let mut maps: Vec<BreakMap> = stream.jobs.iter().map(|j| BreakMap::zeros(j.m)).collect();
        let st = self.state.as_mut().expect("state built above");
        let mut staged = false;
        let mut resid: Option<Vec<f32>> = None;
        let mut strip: Option<Vec<f32>> = None;
        let mut out: Option<BreakMap> = None;
        let parent = trace::current_handle();
        for (i, op) in stream.ops.iter().enumerate() {
            let _sp = trace::span_under(&parent, op.name())
                .map(|s| s.with_attr("job", op.job()).with_attr("chunk", op.chunk()));
            match op {
                Op::StageGather { data, .. } => {
                    times.time(OP_STAGE, || st.stage.data_mut().copy_from_slice(data));
                    staged = true;
                    resid = None;
                    strip = None;
                    out = None;
                }
                Op::FillColumns { .. } => {
                    ensure!(staged, "op {i} (fill_columns) has no staged chunk");
                    times.time(OP_FILL, || fill::fill_columns(st.stage.data_mut(), n_total, mc));
                }
                Op::BatchedFit { .. } => {
                    ensure!(staged, "op {i} (batched_fit) has no staged chunk");
                    resid = Some(times.time(OP_FIT, || st.engine.fit_residuals(&st.stage))?);
                }
                Op::Mosum { .. } => {
                    let Some(r) = &resid else {
                        bail!("op {i} (mosum) has no residuals: batched_fit must precede it");
                    };
                    strip = Some(times.time(OP_MOSUM, || st.engine.mosum_strip(r, mc))?);
                }
                Op::DetectBreaks { .. } => {
                    let Some(s) = &strip else {
                        bail!("op {i} (detect_breaks) has no strip: mosum must precede it");
                    };
                    out = Some(times.time(OP_DETECT, || st.engine.detect_from_strip(s, mc))?);
                }
                Op::Readback { job, start, width, .. } => {
                    let Some(o) = &out else {
                        bail!("op {i} (readback) has no outputs: detect_breaks must precede it");
                    };
                    let (a, w) = (*start as usize, *width as usize);
                    let dst = &mut maps[*job as usize];
                    times.time(OP_READBACK, || {
                        dst.write_at(a, &o.breaks[..w], &o.first[..w], &o.momax[..w])
                    });
                }
            }
        }
        Ok(maps)
    }
}

/// Replay a stream offline and wrap each job's map in the v1 result
/// envelope. The envelope is **deterministic** — zero wall time, no
/// phase table, [`REPLAY_ENGINE`] labels — so re-executing the same
/// `.bcmd` yields byte-identical result JSON (the CI replay-smoke job
/// diffs exactly this against the recording run's envelope).
pub fn replay_to_results(stream: &CmdStream) -> Result<Vec<AnalysisResult>> {
    let params = stream.header.params()?;
    let mut replay = ReplayExecutor::new();
    let mut op_times = PhaseTimes::new();
    let maps = replay.execute(stream, &mut op_times)?;
    let mut out = Vec::with_capacity(maps.len());
    for (ji, (job, map)) in stream.jobs.iter().zip(maps).enumerate() {
        out.push(AnalysisResult {
            map,
            params: params.clone(),
            phases: None,
            chunks: stream.chunks_of(ji as u32),
            artifact: REPLAY_ENGINE.to_string(),
            engine: REPLAY_ENGINE.to_string(),
            wall: Duration::ZERO,
            width: job.width,
            height: job.height,
        });
    }
    Ok(out)
}

/// Record-then-replay as a first-class [`ExecutorBackend`]
/// (`--engine cmd`): every chunk the coordinator stages is recorded
/// into a single-chunk stream and replayed through the interpreter,
/// so results flow through the exact op path an offline `.bcmd`
/// replay uses.
#[derive(Clone, Debug)]
pub struct CmdBackend {
    m_chunk: usize,
    threads: usize,
}

impl Default for CmdBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl CmdBackend {
    pub fn new() -> Self {
        Self {
            m_chunk: crate::runtime::emulated::DEFAULT_M_CHUNK,
            threads: threadpool::default_threads(),
        }
    }

    /// Override the chunk width (≥ 1).
    pub fn with_m_chunk(mut self, m_chunk: usize) -> Self {
        self.m_chunk = m_chunk.max(1);
        self
    }

    /// Override the compute thread count (≥ 1).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }
}

impl ExecutorBackend for CmdBackend {
    fn platform(&self) -> String {
        format!("cmd replay ({} threads)", self.threads)
    }

    fn resolve(&self, artifact: Option<&str>, params: &BfastParams) -> Result<ArtifactSpec> {
        let (n_total, n_hist, h, k) = (params.n_total, params.n_hist, params.h, params.k);
        let mc = self.m_chunk;
        let f32_spec = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            shape,
            dtype: Dtype::F32,
        };
        Ok(ArtifactSpec {
            name: artifact.unwrap_or("cmdstream").to_string(),
            phase: "cmd".to_string(),
            path: std::path::PathBuf::new(),
            n_total,
            n_hist,
            h,
            k,
            p: 2 + 2 * k,
            m_chunk: mc,
            use_pallas: false,
            inputs: vec![
                f32_spec("t", vec![n_total]),
                f32_spec("f", vec![]),
                f32_spec("y", vec![n_total, mc]),
                f32_spec("lam", vec![]),
            ],
            outputs: vec![
                TensorSpec { name: "breaks".into(), shape: vec![mc], dtype: Dtype::I32 },
                TensorSpec { name: "first".into(), shape: vec![mc], dtype: Dtype::I32 },
                f32_spec("momax", vec![mc]),
            ],
        })
    }

    fn load<'a>(
        &'a self,
        spec: &ArtifactSpec,
        phased: bool,
    ) -> Result<Box<dyn ChunkExecutor + 'a>> {
        ensure!(spec.m_chunk >= 1, "m_chunk must be >= 1, got {}", spec.m_chunk);
        Ok(Box::new(CmdChunkExecutor {
            spec: spec.clone(),
            phased,
            replay: ReplayExecutor::new().with_threads(self.threads),
        }))
    }

    /// Replay runs any chunk width — the stream carries its own.
    fn flexible_chunk(&self) -> bool {
        true
    }
}

struct CmdChunkExecutor {
    spec: ArtifactSpec,
    phased: bool,
    /// Persists across chunks: the translation cache makes every
    /// chunk after the first replay against the already-prepared
    /// engine.
    replay: ReplayExecutor,
}

impl ChunkExecutor for CmdChunkExecutor {
    fn run_chunk(
        &mut self,
        t_axis: &[f32],
        freq: f32,
        y: &[f32],
        lambda: f32,
        times: &mut PhaseTimes,
    ) -> Result<ChunkOutput> {
        let spec = &self.spec;
        ensure!(
            t_axis.len() == spec.n_total,
            "t axis len {} != N {}",
            t_axis.len(),
            spec.n_total
        );
        ensure!(
            y.len() == spec.n_total * spec.m_chunk,
            "chunk len {} != N*m_chunk {}",
            y.len(),
            spec.n_total * spec.m_chunk
        );
        // Record the chunk as a single-chunk stream. The coordinator
        // already gap-filled during staging, so no fill op is emitted
        // (fill_missing = false in the header).
        let stream = times.time(PHASE_TRANSFER, || -> Result<CmdStream> {
            let header = StreamHeader {
                n_total: spec.n_total,
                n_hist: spec.n_hist,
                h: spec.h,
                k: spec.k,
                freq: freq as f64,
                alpha: 0.05,
                lambda: lambda as f64,
                m_chunk: spec.m_chunk,
                fill_missing: false,
                t_axis: t_axis.to_vec(),
                freq32: freq,
                lambda32: lambda,
            };
            let mut rec = Recorder::new(header)?;
            let job = rec.begin_job("chunk", spec.m_chunk, None, None);
            rec.record_chunk(job, 0, 0, spec.m_chunk, y.to_vec())?;
            Ok(rec.finish())
        })?;
        let mut op_times = PhaseTimes::new();
        let maps = if self.phased {
            self.replay.execute(&stream, &mut op_times)?
        } else {
            times.time(PHASE_FUSED, || self.replay.execute(&stream, &mut op_times))?
        };
        if self.phased {
            // Surface the per-op phase names instead of one fused
            // bucket.
            times.merge(&op_times);
        }
        let map = maps.into_iter().next().context("replay produced no job results")?;
        times.time(PHASE_READBACK, || {
            Ok(ChunkOutput { breaks: map.breaks, first: map.first, momax: map.momax })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::{record_stream, RecordJob};
    use super::*;
    use crate::synth::ArtificialDataset;

    fn params() -> BfastParams {
        BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 2.5).unwrap()
    }

    fn scene(m: usize, seed: u64) -> TimeStack {
        ArtificialDataset::new(params(), m, seed).generate().stack
    }

    fn direct_map(stack: &TimeStack) -> BreakMap {
        let p = params();
        let (map, _) = FusedCpuBfast::new(p, &stack.time_axis).unwrap().run(stack).unwrap();
        map
    }

    #[test]
    fn replayed_stream_matches_the_direct_run_bitwise() {
        let p = params();
        let stack = scene(150, 7);
        let stream = record_stream(
            &[RecordJob { tag: "a".into(), stack: &stack, params: &p }],
            64,
            true,
        )
        .unwrap();
        let mut times = PhaseTimes::new();
        let maps = ReplayExecutor::new().execute(&stream, &mut times).unwrap();
        let want = direct_map(&stack);
        assert_eq!(maps.len(), 1);
        assert_eq!(maps[0].breaks, want.breaks);
        assert_eq!(maps[0].first, want.first);
        let same = maps[0]
            .momax
            .iter()
            .zip(&want.momax)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "momax must be bit-identical");
        for ph in [OP_STAGE, OP_FILL, OP_FIT, OP_MOSUM, OP_DETECT, OP_READBACK] {
            assert!(times.get(ph).is_some(), "missing op phase {ph}");
        }
    }

    #[test]
    fn multi_job_replay_keeps_per_job_results_independent() {
        let p = params();
        let (a, b) = (scene(33, 8), scene(50, 9));
        let stream = record_stream(
            &[
                RecordJob { tag: "a".into(), stack: &a, params: &p },
                RecordJob { tag: "b".into(), stack: &b, params: &p },
            ],
            16,
            true,
        )
        .unwrap();
        let mut times = PhaseTimes::new();
        let maps = ReplayExecutor::new().execute(&stream, &mut times).unwrap();
        assert_eq!(maps.len(), 2);
        assert_eq!(maps[0].breaks, direct_map(&a).breaks);
        assert_eq!(maps[1].breaks, direct_map(&b).breaks);
    }

    #[test]
    fn out_of_sequence_ops_are_rejected() {
        let p = params();
        let stack = scene(10, 3);
        let ok = record_stream(
            &[RecordJob { tag: "a".into(), stack: &stack, params: &p }],
            10,
            true,
        )
        .unwrap();
        // a mosum with no preceding fit
        let mut bad = ok.clone();
        bad.ops = vec![bad.ops[0].clone(), Op::Mosum { job: 0, chunk: 0 }];
        let err = ReplayExecutor::new()
            .execute(&bad, &mut PhaseTimes::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("batched_fit"), "{err}");
        // a readback with no detection
        let mut bad = ok;
        bad.ops = vec![Op::Readback { job: 0, chunk: 0, start: 0, width: 1 }];
        let err = ReplayExecutor::new()
            .execute(&bad, &mut PhaseTimes::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("detect_breaks"), "{err}");
    }

    #[test]
    fn deterministic_envelopes_from_offline_replay() {
        let p = params();
        let stack = scene(24, 5);
        let stream = record_stream(
            &[RecordJob { tag: "a".into(), stack: &stack, params: &p }],
            16,
            true,
        )
        .unwrap();
        let res_a = replay_to_results(&stream).unwrap();
        let res_b = replay_to_results(&stream).unwrap();
        assert_eq!(res_a.len(), 1);
        assert_eq!(res_a[0].engine, REPLAY_ENGINE);
        assert_eq!(res_a[0].chunks, 2);
        assert_eq!(res_a[0].wall, Duration::ZERO);
        // byte-identical wire envelopes on re-execution
        assert_eq!(
            res_a[0].to_json().to_string_pretty(),
            res_b[0].to_json().to_string_pretty()
        );
        assert_eq!(res_a[0].map.breaks, direct_map(&stack).breaks);
    }
}
