//! PJRT runtime (feature `pjrt`) — loads the AOT artifacts and
//! executes them on the request path. Python never runs here: the HLO
//! text emitted once by `python/compile/aot.py` is parsed, compiled
//! and executed through the `xla` crate (PJRT C API).
//!
//! The interchange format is HLO **text**: jax ≥ 0.5 serialises
//! HloModuleProto with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; `HloModuleProto::from_text_file` reassigns ids.
//!
//! PJRT handles are not `Send`; the coordinator owns the runtime on a
//! single executor thread (the analogue of a CUDA-stream owner) and
//! feeds it staged chunks through channels.
//!
//! In the offline default build this module is compiled out; with
//! `--features pjrt` it builds against `vendor/xla-stub` unless the
//! real `xla` crate is linked in (see rust/Cargo.toml).

use super::{
    ArtifactSpec, ChunkExecutor, ChunkOutput, ExecutorBackend, Manifest, PHASE_DETECT,
    PHASE_FUSED, PHASE_MODEL, PHASE_MOSUM, PHASE_PREDICT, PHASE_READBACK, PHASE_TRANSFER,
};
use crate::error::{ensure, Context, Result};
use crate::metrics::PhaseTimes;
use crate::params::BfastParams;
use std::collections::HashMap;
use std::rc::Rc;

/// The PJRT device + compiled-executable cache.
pub struct DeviceRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: std::cell::RefCell<HashMap<(String, String), Rc<xla::PjRtLoadedExecutable>>>,
}

impl DeviceRuntime {
    /// Open the device and load the artifact manifest.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT client")?;
        Ok(Self { client, manifest, cache: Default::default() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        format!("{} ({})", self.client.platform_name(), self.client.platform_version())
    }

    /// Compile (or fetch from cache) the executable for (name, phase).
    pub fn load_executable(
        &self,
        name: &str,
        phase: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = (name.to_string(), phase.to_string());
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.find(name, phase)?;
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .with_context(|| format!("parsing HLO text {}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}/{phase}"))?,
        );
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Build the fused single-executable pipeline for a config.
    pub fn fused(&self, name: &str) -> Result<FusedPipeline<'_>> {
        let spec = self.manifest.find(name, "fused")?.clone();
        let exe = self.load_executable(name, "fused")?;
        let wmat = crate::mosum::window_matrix_f32(spec.n_total, spec.n_hist, spec.h);
        Ok(FusedPipeline { rt: self, spec, exe, wmat })
    }

    /// Build the phase-instrumented pipeline for a config.
    pub fn phased(&self, name: &str) -> Result<PhasedPipeline<'_>> {
        let spec = self
            .manifest
            .find(name, "fused")
            .or_else(|_| self.manifest.find(name, "fit"))?
            .clone();
        let wmat = crate::mosum::window_matrix_f32(spec.n_total, spec.n_hist, spec.h);
        Ok(PhasedPipeline {
            spec,
            wmat,
            fit: self.load_executable(name, "fit")?,
            predict: self.load_executable(name, "predict")?,
            mosum: self.load_executable(name, "mosum")?,
            detect: self.load_executable(name, "detect")?,
            rt: self,
        })
    }

    fn to_device_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("host->device transfer")
    }
}

impl ExecutorBackend for DeviceRuntime {
    fn platform(&self) -> String {
        DeviceRuntime::platform(self)
    }

    fn resolve(&self, artifact: Option<&str>, params: &BfastParams) -> Result<ArtifactSpec> {
        let name = match artifact {
            Some(n) => n.to_string(),
            None => self
                .manifest
                .find_fused_for(params.n_total, params.n_hist, params.h, params.k)?
                .name
                .clone(),
        };
        Ok(self
            .manifest
            .find(&name, "fused")
            .or_else(|_| self.manifest.find(&name, "fit"))?
            .clone())
    }

    fn load<'a>(
        &'a self,
        spec: &ArtifactSpec,
        phased: bool,
    ) -> Result<Box<dyn ChunkExecutor + 'a>> {
        if phased {
            Ok(Box::new(self.phased(&spec.name)?))
        } else {
            Ok(Box::new(self.fused(&spec.name)?))
        }
    }
}

/// Decode the (breaks, first, momax) tuple output of fused/detect.
fn decode_detect_tuple(bufs: Vec<Vec<xla::PjRtBuffer>>) -> Result<ChunkOutput> {
    ensure!(!bufs.is_empty() && !bufs[0].is_empty(), "executable produced no output");
    let lit = bufs[0][0].to_literal_sync()?;
    let parts = lit.to_tuple()?;
    ensure!(parts.len() == 3, "expected 3-tuple output, got {}", parts.len());
    Ok(ChunkOutput {
        breaks: parts[0].to_vec::<i32>()?,
        first: parts[1].to_vec::<i32>()?,
        momax: parts[2].to_vec::<f32>()?,
    })
}

/// The production path: one fused executable per chunk.
pub struct FusedPipeline<'rt> {
    rt: &'rt DeviceRuntime,
    pub spec: ArtifactSpec,
    exe: Rc<xla::PjRtLoadedExecutable>,
    /// Banded window operator, rebuilt from the manifest shape.
    wmat: Vec<f32>,
}

impl FusedPipeline<'_> {
    /// Execute one padded chunk: `y` is time-major (N × m_chunk).
    /// Phase accounting: `transfer` (host→device staging of Y and the
    /// small params), `fused execute`, `readback`.
    pub fn run_chunk(
        &self,
        t_axis: &[f32],
        freq: f32,
        y: &[f32],
        lambda: f32,
        times: &mut PhaseTimes,
    ) -> Result<ChunkOutput> {
        let spec = &self.spec;
        ensure!(t_axis.len() == spec.n_total, "t axis len {} != N {}", t_axis.len(), spec.n_total);
        ensure!(
            y.len() == spec.n_total * spec.m_chunk,
            "chunk len {} != N*m_chunk {}",
            y.len(),
            spec.n_total * spec.m_chunk
        );
        let bufs = times.time(PHASE_TRANSFER, || -> Result<_> {
            Ok([
                self.rt.to_device_f32(t_axis, &[spec.n_total])?,
                self.rt.to_device_f32(&[freq], &[])?,
                self.rt
                    .to_device_f32(&self.wmat, &[spec.n_monitor(), spec.n_total])?,
                self.rt.to_device_f32(y, &[spec.n_total, spec.m_chunk])?,
                self.rt.to_device_f32(&[lambda], &[])?,
            ])
        })?;
        let out = times.time(PHASE_FUSED, || self.exe.execute_b(&bufs))?;
        times.time(PHASE_READBACK, || decode_detect_tuple(out))
    }
}

impl ChunkExecutor for FusedPipeline<'_> {
    fn run_chunk(
        &mut self,
        t_axis: &[f32],
        freq: f32,
        y: &[f32],
        lambda: f32,
        times: &mut PhaseTimes,
    ) -> Result<ChunkOutput> {
        FusedPipeline::run_chunk(self, t_axis, freq, y, lambda, times)
    }
}

/// The instrumented path: four executables, one per paper phase —
/// used by the Fig. 3–6 benches only (the production path is
/// [`FusedPipeline`]).
///
/// Intermediates are passed between phases as host literals: CPU PJRT
/// aliases buffers across `execute_b` calls (donation), which corrupts
/// reused inputs, so the buffer-resident variant is unsound on this
/// backend. The literal round-trip cost is charged to the phase that
/// produced the intermediate — an explicit, measured penalty of phased
/// mode that the fused path does not pay.
pub struct PhasedPipeline<'rt> {
    rt: &'rt DeviceRuntime,
    pub spec: ArtifactSpec,
    wmat: Vec<f32>,
    fit: Rc<xla::PjRtLoadedExecutable>,
    predict: Rc<xla::PjRtLoadedExecutable>,
    mosum: Rc<xla::PjRtLoadedExecutable>,
    detect: Rc<xla::PjRtLoadedExecutable>,
}

impl PhasedPipeline<'_> {
    pub fn run_chunk(
        &self,
        t_axis: &[f32],
        freq: f32,
        y: &[f32],
        lambda: f32,
        times: &mut PhaseTimes,
    ) -> Result<ChunkOutput> {
        let spec = &self.spec;
        let (n, nh, mc) = (spec.n_total, spec.n_hist, spec.m_chunk);
        ensure!(y.len() == n * mc, "chunk len {} != N*m_chunk {}", y.len(), n * mc);
        let _ = self.rt; // runtime keeps the client (and executables) alive
        // transfer: exactly what the paper ships to the device — the
        // design-side scalars + the full Y (plus its history prefix,
        // which the fit module consumes directly).
        let (t_lit, f_lit, w_lit, y_lit, lam_lit, yh_lit) =
            times.time(PHASE_TRANSFER, || -> Result<_> {
                Ok((
                    lit_f32(t_axis, &[n])?,
                    xla::Literal::scalar(freq),
                    lit_f32(&self.wmat, &[n - nh, n])?,
                    lit_f32(y, &[n, mc])?,
                    xla::Literal::scalar(lambda),
                    lit_f32(&y[..nh * mc], &[nh, mc])?,
                ))
            })?;
        let beta = times.time(PHASE_MODEL, || -> Result<_> {
            tuple1_literal(self.fit.execute(&[&t_lit, &f_lit, &yh_lit])?)
        })?;
        let yhat = times.time(PHASE_PREDICT, || -> Result<_> {
            tuple1_literal(self.predict.execute(&[&t_lit, &f_lit, &beta])?)
        })?;
        let mo = times.time(PHASE_MOSUM, || -> Result<_> {
            tuple1_literal(self.mosum.execute(&[&w_lit, &y_lit, &yhat])?)
        })?;
        let out = times.time(PHASE_DETECT, || self.detect.execute(&[&mo, &lam_lit]))?;
        times.time(PHASE_READBACK, || decode_detect_tuple(out))
    }
}

impl ChunkExecutor for PhasedPipeline<'_> {
    fn run_chunk(
        &mut self,
        t_axis: &[f32],
        freq: f32,
        y: &[f32],
        lambda: f32,
        times: &mut PhaseTimes,
    ) -> Result<ChunkOutput> {
        PhasedPipeline::run_chunk(self, t_axis, freq, y, lambda, times)
    }
}

/// Build an f32 literal of the given shape from a host slice.
fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .context("building literal")
}

/// Unwrap a 1-tuple executable output into a host literal.
fn tuple1_literal(bufs: Vec<Vec<xla::PjRtBuffer>>) -> Result<xla::Literal> {
    ensure!(!bufs.is_empty() && !bufs[0].is_empty(), "no output");
    let lit = bufs[0][0].to_literal_sync()?;
    let mut parts = lit.to_tuple()?;
    ensure!(parts.len() == 1, "expected 1-tuple, got {}", parts.len());
    Ok(parts.pop().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Artifacts are produced by `make artifacts`; most runtime tests
    /// live in `rust/tests/` (integration). Here: graceful failure.
    #[test]
    fn missing_dir_is_clean_error() {
        let err = match DeviceRuntime::new("/nonexistent/artifacts") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest.json"), "{msg}");
    }
}
