//! Executor backends — the device boundary of the pipeline.
//!
//! The coordinator never talks to a device directly; it talks to an
//! [`ExecutorBackend`] that resolves an analysis shape to a chunk
//! contract ([`manifest::ArtifactSpec`]) and loads a [`ChunkExecutor`]
//! that runs padded `N × m_chunk` chunks to [`ChunkOutput`]s. Three
//! implementations ship:
//!
//! * [`EmulatedDevice`] (**default build**) — a pure-rust emulator
//!   executing the same batched BFAST pipeline (history OLS fit →
//!   predictions → MOSUM → break scan) on the `threadpool` + `linalg`
//!   substrate. No artifacts, no network, no native deps; every test
//!   and bench runs against it out of the box.
//! * [`pjrt::DeviceRuntime`] (**feature `pjrt`**) — loads the AOT HLO
//!   artifacts emitted by `python/compile/aot.py` and executes them
//!   through the `xla` crate's PJRT client (see `pjrt` module docs).
//! * [`crate::cmd::CmdBackend`] — record-then-replay: each staged
//!   chunk becomes a single-chunk command stream executed by the
//!   `cmd` interpreter, so the coordinator path and an offline
//!   `bfast replay` share one op pipeline (bit-identical results).
//!
//! PJRT handles are not `Send`; the coordinator owns whichever backend
//! on a single executor thread (the analogue of a CUDA-stream owner)
//! and feeds it staged chunks through channels — the emulator honours
//! the same single-threaded-executor contract.

pub mod bten;
pub mod emulated;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use emulated::EmulatedDevice;
pub use manifest::{ArtifactSpec, Dtype, Manifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use pjrt::{DeviceRuntime, FusedPipeline, PhasedPipeline};

use crate::error::Result;
use crate::metrics::PhaseTimes;
use crate::params::BfastParams;

/// Phase names used by the device path (Fig. 3(b) analogues).
pub const PHASE_TRANSFER: &str = "transfer";
pub const PHASE_MODEL: &str = "create model";
pub const PHASE_PREDICT: &str = "predictions";
pub const PHASE_MOSUM: &str = "mosum";
pub const PHASE_DETECT: &str = "detect breaks";
pub const PHASE_FUSED: &str = "fused execute";
pub const PHASE_READBACK: &str = "readback";

/// Results of one executed chunk (padded width = `m_chunk`).
#[derive(Clone, Debug)]
pub struct ChunkOutput {
    pub breaks: Vec<i32>,
    pub first: Vec<i32>,
    pub momax: Vec<f32>,
}

/// A loaded/compiled executor for one chunk contract.
///
/// `y` is time-major (`n_total × m_chunk`, padded); outputs cover the
/// full padded width — the coordinator discards pad columns. `&mut`
/// because executors may lazily build / cache design-side state on
/// first use (the emulator) or own non-reentrant device handles.
pub trait ChunkExecutor {
    fn run_chunk(
        &mut self,
        t_axis: &[f32],
        freq: f32,
        y: &[f32],
        lambda: f32,
        times: &mut PhaseTimes,
    ) -> Result<ChunkOutput>;
}

/// A device backend: resolves analysis shapes to chunk contracts and
/// loads executors for them.
pub trait ExecutorBackend {
    /// Human-readable platform description (CLI `info`, logs).
    fn platform(&self) -> String;

    /// Resolve the chunk contract for an analysis: pick (or
    /// synthesize) the artifact matching `params`, optionally forced
    /// by name. The returned spec's shape may disagree with `params`
    /// when the backend is shape-specialised — the coordinator
    /// rejects such runs.
    fn resolve(&self, artifact: Option<&str>, params: &BfastParams) -> Result<ArtifactSpec>;

    /// Compile/load the executor for a resolved spec. `phased` selects
    /// the per-phase instrumented path (paper Figs. 3–6) over the
    /// fused production path.
    fn load<'a>(
        &'a self,
        spec: &ArtifactSpec,
        phased: bool,
    ) -> Result<Box<dyn ChunkExecutor + 'a>>;

    /// Whether the backend accepts an arbitrary `m_chunk` after
    /// [`ExecutorBackend::resolve`]. Shape-specialised AOT artifacts
    /// (the PJRT path) are compiled for one chunk width and cannot;
    /// the emulator can run any width. When `true`, the coordinator
    /// may override the resolved spec's `m_chunk` (e.g. from the
    /// bench harness's chunk autotuner).
    fn flexible_chunk(&self) -> bool {
        false
    }
}
