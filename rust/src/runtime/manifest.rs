//! Artifact manifest — the contract between `python/compile/aot.py`
//! and the rust runtime. Parsed from `artifacts/manifest.json`.

use crate::json::{self, Value};
use crate::error::{ensure, err, Context, Result};
use std::path::{Path, PathBuf};

/// Dtype of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(err!("unsupported dtype {other:?}")),
        }
    }
}

/// One named tensor port of an artifact.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: Dtype::parse(v.get("dtype")?.as_str()?)?,
        })
    }
}

/// One AOT-lowered HLO module (a (config, phase) pair).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub phase: String,
    pub path: PathBuf,
    pub n_total: usize,
    pub n_hist: usize,
    pub h: usize,
    pub k: usize,
    pub p: usize,
    pub m_chunk: usize,
    pub use_pallas: bool,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    pub fn n_monitor(&self) -> usize {
        self.n_total - self.n_hist
    }
}

/// The parsed manifest: all artifacts of an `artifacts/` directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let man_path = dir.join("manifest.json");
        let doc = json::parse_file(&man_path)?;
        let version = doc.get("version")?.as_usize()?;
        ensure!(version == 1, "manifest version {version} unsupported (want 1)");
        let mut artifacts = Vec::new();
        for a in doc.get("artifacts")?.as_arr()? {
            let name = a.get("name")?.as_str()?.to_string();
            let phase = a.get("phase")?.as_str()?.to_string();
            let file = a.get("file")?.as_str()?;
            let spec = ArtifactSpec {
                path: dir.join(file),
                n_total: a.get("n_total")?.as_usize()?,
                n_hist: a.get("n_hist")?.as_usize()?,
                h: a.get("h")?.as_usize()?,
                k: a.get("k")?.as_usize()?,
                p: a.get("p")?.as_usize()?,
                m_chunk: a.get("m_chunk")?.as_usize()?,
                use_pallas: a.get("use_pallas")?.as_bool()?,
                inputs: a
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<_>>()
                    .with_context(|| format!("inputs of {name}/{phase}"))?,
                outputs: a
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<_>>()
                    .with_context(|| format!("outputs of {name}/{phase}"))?,
                name,
                phase,
            };
            ensure!(
                spec.path.exists(),
                "artifact file missing: {} (run `make artifacts`)",
                spec.path.display()
            );
            artifacts.push(spec);
        }
        Ok(Self { dir, artifacts })
    }

    /// Find a (config, phase) artifact.
    pub fn find(&self, name: &str, phase: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name && a.phase == phase)
            .ok_or_else(|| {
                err!(
                    "no artifact {name}/{phase} in {} (have: {})",
                    self.dir.display(),
                    self.names().join(", ")
                )
            })
    }

    /// Distinct config names.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.artifacts.iter().map(|a| a.name.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    /// Pick a fused config compatible with the given analysis shape
    /// (N, n, h, k), preferring pallas variants, any m_chunk.
    pub fn find_fused_for(
        &self,
        n_total: usize,
        n_hist: usize,
        h: usize,
        k: usize,
    ) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.phase == "fused"
                    && a.n_total == n_total
                    && a.n_hist == n_hist
                    && a.h == h
                    && a.k == k
            })
            .max_by_key(|a| (a.use_pallas, a.m_chunk))
            .ok_or_else(|| {
                err!(
                    "no fused artifact for N={n_total} n={n_hist} h={h} k={k}; \
                     add the variant in python/compile/aot.py and re-run `make artifacts`"
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn spec_json(dir: &Path) -> String {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("a__fused.hlo.txt"), "HloModule x").unwrap();
        r#"{"version":1,"artifacts":[{
            "name":"a","phase":"fused","file":"a__fused.hlo.txt",
            "n_total":200,"n_hist":100,"h":50,"k":3,"p":8,"m_chunk":1024,
            "use_pallas":true,
            "inputs":[{"name":"t","shape":[200],"dtype":"f32"},
                      {"name":"y","shape":[200,1024],"dtype":"f32"}],
            "outputs":[{"name":"breaks","shape":[1024],"dtype":"i32"}]}]}"#
            .to_string()
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join(format!("bfast_man_{}", std::process::id()));
        write_manifest(&dir, &spec_json(&dir));
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.names(), vec!["a"]);
        let a = m.find("a", "fused").unwrap();
        assert_eq!(a.m_chunk, 1024);
        assert_eq!(a.inputs[1].elements(), 200 * 1024);
        assert_eq!(a.outputs[0].dtype, Dtype::I32);
        assert!(m.find("a", "fit").is_err());
        assert!(m.find_fused_for(200, 100, 50, 3).is_ok());
        assert!(m.find_fused_for(100, 50, 25, 3).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_file_is_an_error() {
        let dir = std::env::temp_dir().join(format!("bfast_man2_{}", std::process::id()));
        write_manifest(&dir, &spec_json(&dir));
        std::fs::remove_file(dir.join("a__fused.hlo.txt")).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn version_check() {
        let dir = std::env::temp_dir().join(format!("bfast_man3_{}", std::process::id()));
        write_manifest(&dir, r#"{"version":2,"artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
