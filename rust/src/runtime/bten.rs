//! `.bten` tensor container reader/writer — golden-vector interchange
//! with the python oracle (written by `aot.py --golden` and
//! `golden_fixtures.py`) and the monitor session's persisted state.
//!
//! Layout: `b"BTEN" | u8 dtype (0=f32, 1=i32, 2=f64) | u8 ndim |
//! ndim × u32 LE dims | raw LE data`.

use crate::error::{bail, ensure, Context, Result};
use std::path::Path;

/// A loaded tensor (data flattened, row-major).
#[derive(Clone, Debug)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    F64 { shape: Vec<usize>, data: Vec<f64> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } | Tensor::F64 { shape, .. } => {
                shape
            }
        }
    }

    pub fn as_f64_vec(&self) -> Vec<f64> {
        match self {
            Tensor::F32 { data, .. } => data.iter().map(|&x| x as f64).collect(),
            Tensor::I32 { data, .. } => data.iter().map(|&x| x as f64).collect(),
            Tensor::F64 { data, .. } => data.clone(),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f64(&self) -> Result<&[f64]> {
        match self {
            Tensor::F64 { data, .. } => Ok(data),
            _ => bail!("tensor is not f64"),
        }
    }

    fn dtype_code(&self) -> u8 {
        match self {
            Tensor::F32 { .. } => 0,
            Tensor::I32 { .. } => 1,
            Tensor::F64 { .. } => 2,
        }
    }

    fn element_count(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
            Tensor::F64 { data, .. } => data.len(),
        }
    }
}

/// Serialise one tensor into `.bten` bytes (exact round-trip through
/// [`bten_from_bytes`], including NaN payloads — monitor state and
/// the serving API's layer-ingest bodies rely on this).
pub fn bten_to_bytes(tensor: &Tensor) -> Result<Vec<u8>> {
    let shape = tensor.shape();
    let count: usize = shape.iter().product();
    ensure!(
        count == tensor.element_count(),
        "tensor shape {:?} does not match {} elements",
        shape,
        tensor.element_count()
    );
    ensure!(shape.len() <= u8::MAX as usize, "too many dims");
    let mut bytes = Vec::with_capacity(6 + 4 * shape.len() + count * 8);
    bytes.extend_from_slice(b"BTEN");
    bytes.push(tensor.dtype_code());
    bytes.push(shape.len() as u8);
    for &d in shape {
        ensure!(d <= u32::MAX as usize, "dim {d} exceeds u32");
        bytes.extend_from_slice(&(d as u32).to_le_bytes());
    }
    match tensor {
        Tensor::F32 { data, .. } => {
            for v in data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        Tensor::I32 { data, .. } => {
            for v in data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        Tensor::F64 { data, .. } => {
            for v in data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    Ok(bytes)
}

/// Parse one tensor from `.bten` bytes. `label` names the source in
/// errors (a path, a request body, …).
pub fn bten_from_bytes(bytes: &[u8], label: &str) -> Result<Tensor> {
    ensure!(bytes.len() >= 6 && &bytes[..4] == b"BTEN", "{label}: bad magic");
    let dtype = bytes[4];
    let ndim = bytes[5] as usize;
    let mut off = 6;
    ensure!(bytes.len() >= off + 4 * ndim, "{label}: truncated dims");
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize);
        off += 4;
    }
    let count: usize = shape.iter().product();
    let payload = &bytes[off..];
    match dtype {
        0 => {
            ensure!(payload.len() == count * 4, "{label}: f32 payload size");
            let data = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Tensor::F32 { shape, data })
        }
        1 => {
            ensure!(payload.len() == count * 4, "{label}: i32 payload size");
            let data = payload
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Tensor::I32 { shape, data })
        }
        2 => {
            ensure!(payload.len() == count * 8, "{label}: f64 payload size");
            let data = payload
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Tensor::F64 { shape, data })
        }
        other => bail!("{label}: unknown dtype code {other}"),
    }
}

/// Write one `.bten` file (exact round-trip through [`read_bten`],
/// including NaN payloads — monitor state relies on this).
pub fn write_bten(path: impl AsRef<Path>, tensor: &Tensor) -> Result<()> {
    let path = path.as_ref();
    let bytes = bten_to_bytes(tensor)?;
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

/// Read one `.bten` file.
pub fn read_bten(path: impl AsRef<Path>) -> Result<Tensor> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    bten_from_bytes(&bytes, &path.display().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_case(path: &Path, dtype: u8, dims: &[u32], payload: &[u8]) {
        let mut b = b"BTEN".to_vec();
        b.push(dtype);
        b.push(dims.len() as u8);
        for d in dims {
            b.extend_from_slice(&d.to_le_bytes());
        }
        b.extend_from_slice(payload);
        std::fs::write(path, b).unwrap();
    }

    #[test]
    fn reads_all_dtypes() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("bfast_bten_{}.bten", std::process::id()));
        // f32 2x2
        let f: Vec<u8> = [1.0f32, 2.0, 3.0, 4.0].iter().flat_map(|v| v.to_le_bytes()).collect();
        write_case(&p, 0, &[2, 2], &f);
        let t = read_bten(&p).unwrap();
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.as_f64_vec(), vec![1.0, 2.0, 3.0, 4.0]);
        // i32 3
        let i: Vec<u8> = [5i32, -6, 7].iter().flat_map(|v| v.to_le_bytes()).collect();
        write_case(&p, 1, &[3], &i);
        assert_eq!(read_bten(&p).unwrap().as_i32().unwrap(), &[5, -6, 7]);
        // f64 scalar-ish
        let d: Vec<u8> = [2.5f64].iter().flat_map(|v| v.to_le_bytes()).collect();
        write_case(&p, 2, &[1], &d);
        assert_eq!(read_bten(&p).unwrap().as_f64_vec(), vec![2.5]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn write_read_roundtrip_all_dtypes() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("bfast_bten_rt_{}.bten", std::process::id()));
        let f = Tensor::F32 { shape: vec![2, 3], data: vec![1.5, -0.0, f32::NAN, 3.0, 4.0, 5.0] };
        write_bten(&p, &f).unwrap();
        let back = read_bten(&p).unwrap();
        assert_eq!(back.shape(), &[2, 3]);
        let data = back.as_f32().unwrap();
        for (a, b) in data.iter().zip(f.as_f32().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 payload must round-trip bitwise");
        }
        let i = Tensor::I32 { shape: vec![3], data: vec![-1, 0, i32::MAX] };
        write_bten(&p, &i).unwrap();
        assert_eq!(read_bten(&p).unwrap().as_i32().unwrap(), &[-1, 0, i32::MAX]);
        let d = Tensor::F64 { shape: vec![2], data: vec![f64::NAN, 2.25] };
        write_bten(&p, &d).unwrap();
        let back = read_bten(&p).unwrap();
        let vals = back.as_f64().unwrap();
        assert!(vals[0].is_nan());
        assert_eq!(vals[1], 2.25);
        // shape mismatch rejected
        let bad = Tensor::F32 { shape: vec![4], data: vec![0.0; 3] };
        assert!(write_bten(&p, &bad).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_bad_input() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("bfast_bten_bad_{}.bten", std::process::id()));
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(read_bten(&p).is_err());
        write_case(&p, 9, &[1], &[0, 0, 0, 0]);
        assert!(read_bten(&p).is_err());
        write_case(&p, 0, &[2], &[0, 0, 0, 0]); // payload too short
        assert!(read_bten(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
