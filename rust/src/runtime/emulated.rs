//! Pure-rust device emulator — the default [`ExecutorBackend`].
//!
//! Executes the exact batched BFAST chunk contract of the AOT device
//! path (history OLS fit → predictions → MOSUM → break/max-deviation
//! outputs over a padded time-major `N × m_chunk` chunk) on the
//! in-tree `threadpool` + `linalg` substrate, by driving the fused
//! multi-core engine ([`FusedCpuBfast`]) per chunk. The arithmetic is
//! identical to the scene-wide CPU implementation, so the coordinator
//! produces bit-identical break maps through this backend — the
//! cross-backend equivalence tests pin that.
//!
//! Phase accounting mirrors the device pipeline: `transfer` is the
//! host→"device" chunk copy, `fused execute` (or the per-phase names
//! in phased mode) is the compute, `readback` the output assembly —
//! so the Fig. 3–6 bench tables render identically against either
//! backend.
//!
//! The emulator is shape-agnostic by default: it synthesizes the
//! chunk contract from the analysis parameters. [`EmulatedDevice::with_shape`]
//! pins it to one shape, reproducing the shape-specialisation
//! constraint of real AOT artifacts (used by tests and by deployments
//! that want the device-like rejection behaviour).

use super::{
    ArtifactSpec, ChunkExecutor, ChunkOutput, Dtype, ExecutorBackend, TensorSpec,
    PHASE_FUSED, PHASE_READBACK, PHASE_TRANSFER,
};
use crate::cpu::FusedCpuBfast;
use crate::error::{ensure, Context, Result};
use crate::metrics::PhaseTimes;
use crate::params::BfastParams;
use crate::raster::TimeStack;
use crate::threadpool;

/// Default chunk width (pixels per executed chunk) — matches the
/// `small`/`default` AOT artifact configurations.
pub const DEFAULT_M_CHUNK: usize = 1024;

/// The pure-rust emulated device backend.
#[derive(Clone, Debug)]
pub struct EmulatedDevice {
    /// Pixels per chunk (the synthesized contract's `m_chunk`).
    m_chunk: usize,
    /// Worker threads for the per-chunk compute.
    threads: usize,
    /// Optional pinned (N, n, h, k) contract shape; `None` = adapt to
    /// whatever the analysis asks for.
    pinned: Option<(usize, usize, usize, usize)>,
}

impl Default for EmulatedDevice {
    fn default() -> Self {
        Self::new()
    }
}

impl EmulatedDevice {
    pub fn new() -> Self {
        Self {
            m_chunk: DEFAULT_M_CHUNK,
            threads: threadpool::default_threads(),
            pinned: None,
        }
    }

    /// Override the chunk width (≥ 1).
    pub fn with_m_chunk(mut self, m_chunk: usize) -> Self {
        self.m_chunk = m_chunk.max(1);
        self
    }

    /// Override the compute thread count (≥ 1).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Pin the contract to one (N, n, h, k) shape, like a real
    /// shape-specialised artifact: analyses with other shapes are
    /// rejected by the coordinator.
    pub fn with_shape(mut self, n_total: usize, n_hist: usize, h: usize, k: usize) -> Self {
        self.pinned = Some((n_total, n_hist, h, k));
        self
    }
}

impl ExecutorBackend for EmulatedDevice {
    fn platform(&self) -> String {
        format!("emulated (pure-rust, {} threads)", self.threads)
    }

    fn resolve(&self, artifact: Option<&str>, params: &BfastParams) -> Result<ArtifactSpec> {
        let (n_total, n_hist, h, k) = self
            .pinned
            .unwrap_or((params.n_total, params.n_hist, params.h, params.k));
        let p = 2 + 2 * k;
        let mc = self.m_chunk;
        let f32_spec = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            shape,
            dtype: Dtype::F32,
        };
        Ok(ArtifactSpec {
            name: artifact.unwrap_or("emulated").to_string(),
            phase: "emulated".to_string(),
            path: std::path::PathBuf::new(),
            n_total,
            n_hist,
            h,
            k,
            p,
            m_chunk: mc,
            use_pallas: false,
            inputs: vec![
                f32_spec("t", vec![n_total]),
                f32_spec("f", vec![]),
                f32_spec("y", vec![n_total, mc]),
                f32_spec("lam", vec![]),
            ],
            outputs: vec![
                TensorSpec { name: "breaks".into(), shape: vec![mc], dtype: Dtype::I32 },
                TensorSpec { name: "first".into(), shape: vec![mc], dtype: Dtype::I32 },
                f32_spec("momax", vec![mc]),
            ],
        })
    }

    fn load<'a>(
        &'a self,
        spec: &ArtifactSpec,
        phased: bool,
    ) -> Result<Box<dyn ChunkExecutor + 'a>> {
        ensure!(spec.m_chunk >= 1, "m_chunk must be >= 1, got {}", spec.m_chunk);
        Ok(Box::new(EmulatedExecutor {
            spec: spec.clone(),
            threads: self.threads,
            phased,
            state: None,
        }))
    }

    /// The emulator runs any chunk width: the coordinator may override
    /// the resolved `m_chunk` (chunk autotuning).
    fn flexible_chunk(&self) -> bool {
        true
    }
}

/// Design-side state built lazily on the first chunk and reused while
/// (t axis, freq, lambda) stay unchanged — the emulator's analogue of
/// the compiled-executable cache.
struct EmState {
    t_bits: Vec<u32>,
    freq_bits: u32,
    lambda_bits: u32,
    engine: FusedCpuBfast,
    /// Reused chunk staging buffer shaped (n_total, m_chunk).
    stack: TimeStack,
}

struct EmulatedExecutor {
    spec: ArtifactSpec,
    threads: usize,
    phased: bool,
    state: Option<EmState>,
}

impl EmulatedExecutor {
    fn ensure_state(&mut self, t_axis: &[f32], freq: f32, lambda: f32) -> Result<()> {
        let fresh = match &self.state {
            Some(st) => {
                st.freq_bits == freq.to_bits()
                    && st.lambda_bits == lambda.to_bits()
                    && st.t_bits.len() == t_axis.len()
                    && st.t_bits.iter().zip(t_axis).all(|(b, t)| *b == t.to_bits())
            }
            None => false,
        };
        if fresh {
            return Ok(());
        }
        let spec = &self.spec;
        let t64: Vec<f64> = t_axis.iter().map(|&v| v as f64).collect();
        // alpha only labels the analysis here; the boundary is fully
        // determined by the lambda shipped with each chunk.
        let params = BfastParams::with_lambda(
            spec.n_total,
            spec.n_hist,
            spec.h,
            spec.k,
            freq as f64,
            0.05,
            lambda as f64,
        )?;
        let engine = FusedCpuBfast::new(params, &t64)?.with_threads(self.threads);
        // The device contract ships the axis as f32; axes whose steps
        // fall below f32 resolution collapse here — fail with context
        // rather than compute on a degenerate design.
        let stack = TimeStack::zeros(spec.n_total, spec.m_chunk)
            .with_time_axis(t64)
            .context("emulated backend: f32-rounded chunk time axis")?;
        self.state = Some(EmState {
            t_bits: t_axis.iter().map(|t| t.to_bits()).collect(),
            freq_bits: freq.to_bits(),
            lambda_bits: lambda.to_bits(),
            engine,
            stack,
        });
        Ok(())
    }
}

impl ChunkExecutor for EmulatedExecutor {
    fn run_chunk(
        &mut self,
        t_axis: &[f32],
        freq: f32,
        y: &[f32],
        lambda: f32,
        times: &mut PhaseTimes,
    ) -> Result<ChunkOutput> {
        let spec = &self.spec;
        ensure!(
            t_axis.len() == spec.n_total,
            "t axis len {} != N {}",
            t_axis.len(),
            spec.n_total
        );
        ensure!(
            y.len() == spec.n_total * spec.m_chunk,
            "chunk len {} != N*m_chunk {}",
            y.len(),
            spec.n_total * spec.m_chunk
        );
        self.ensure_state(t_axis, freq, lambda)?;
        let phased = self.phased;
        let st = self.state.as_mut().expect("state built above");
        times.time(PHASE_TRANSFER, || st.stack.data_mut().copy_from_slice(y));
        let (map, engine_times) = if phased {
            st.engine.run(&st.stack)?
        } else {
            times.time(PHASE_FUSED, || st.engine.run(&st.stack))?
        };
        if phased {
            // Surface the engine's per-phase names (create model /
            // predictions / residuals / mosum / detect breaks).
            times.merge(&engine_times);
        }
        times.time(PHASE_READBACK, || {
            Ok(ChunkOutput { breaks: map.breaks, first: map.first, momax: map.momax })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{PHASE_DETECT, PHASE_MODEL};
    use crate::synth::ArtificialDataset;

    fn params() -> BfastParams {
        BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 2.5).unwrap()
    }

    fn chunk_of(p: &BfastParams, m: usize, mc: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let data = ArtificialDataset::new(p.clone(), m, seed).generate();
        let mut buf = vec![0.0f32; p.n_total * mc];
        data.stack.copy_chunk_padded(0, m, mc, 0.0, &mut buf);
        let t: Vec<f32> = data.stack.time_axis.iter().map(|&v| v as f32).collect();
        (t, buf)
    }

    #[test]
    fn resolve_synthesizes_from_params() {
        let dev = EmulatedDevice::new().with_m_chunk(256);
        let p = params();
        let spec = dev.resolve(None, &p).unwrap();
        assert_eq!(spec.name, "emulated");
        assert_eq!((spec.n_total, spec.n_hist, spec.h, spec.k), (60, 40, 20, 2));
        assert_eq!(spec.m_chunk, 256);
        assert_eq!(spec.p, 6);
        let named = dev.resolve(Some("small"), &p).unwrap();
        assert_eq!(named.name, "small");
    }

    #[test]
    fn pinned_shape_ignores_params() {
        let dev = EmulatedDevice::new().with_shape(200, 100, 50, 3);
        let spec = dev.resolve(None, &params()).unwrap();
        assert_eq!((spec.n_total, spec.n_hist, spec.h, spec.k), (200, 100, 50, 3));
    }

    #[test]
    fn executor_matches_cpu_engine_and_records_phases() {
        let p = params();
        let (m, mc) = (100usize, 128usize);
        let dev = EmulatedDevice::new().with_m_chunk(mc);
        let spec = dev.resolve(None, &p).unwrap();
        let (t, buf) = chunk_of(&p, m, mc, 9);

        // fused mode
        let mut exec = dev.load(&spec, false).unwrap();
        let mut times = PhaseTimes::new();
        let out = exec
            .run_chunk(&t, p.freq as f32, &buf, p.lambda as f32, &mut times)
            .unwrap();
        assert_eq!(out.breaks.len(), mc);
        for ph in [PHASE_TRANSFER, PHASE_FUSED, PHASE_READBACK] {
            assert!(times.get(ph).is_some(), "missing phase {ph}");
        }

        // phased mode records the paper's phase names
        let mut exec_p = dev.load(&spec, true).unwrap();
        let mut times_p = PhaseTimes::new();
        let out_p = exec_p
            .run_chunk(&t, p.freq as f32, &buf, p.lambda as f32, &mut times_p)
            .unwrap();
        for ph in [PHASE_TRANSFER, PHASE_MODEL, PHASE_DETECT] {
            assert!(times_p.get(ph).is_some(), "missing phase {ph}");
        }
        assert_eq!(out.breaks, out_p.breaks);

        // reference: the scene-wide CPU engine on the same pixels
        let data = ArtificialDataset::new(p.clone(), m, 9).generate();
        let (cpu_map, _) = FusedCpuBfast::new(p.clone(), &data.stack.time_axis)
            .unwrap()
            .run(&data.stack)
            .unwrap();
        assert_eq!(&out.breaks[..m], &cpu_map.breaks[..]);
        assert_eq!(&out.first[..m], &cpu_map.first[..]);
        for (a, b) in out.momax[..m].iter().zip(&cpu_map.momax) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn rejects_wrong_chunk_length() {
        let p = params();
        let dev = EmulatedDevice::new().with_m_chunk(64);
        let spec = dev.resolve(None, &p).unwrap();
        let mut exec = dev.load(&spec, false).unwrap();
        let t: Vec<f32> = (1..=60).map(|v| v as f32).collect();
        let y = vec![0.0f32; 10];
        let mut times = PhaseTimes::new();
        assert!(exec.run_chunk(&t, 12.0, &y, 2.5, &mut times).is_err());
    }
}
