//! Offline stub of the `xla` (PJRT) crate surface used by bfast.
//!
//! Compiles with zero dependencies so `--features pjrt` still resolves
//! in an air-gapped build; every device operation fails cleanly at
//! runtime with [`Error`]. Swap this path dependency for the real
//! crate to execute on hardware (see README.md).

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's (std::error::Error + Send + Sync).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub(op: &str) -> Self {
        Error(format!(
            "xla stub: {op} is unavailable — this binary was built against the \
             offline xla-stub crate; link the real `xla` crate to use PJRT devices"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types of device buffers/literals (subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    I32,
    F64,
}

/// Parsed HLO module (never actually constructed by the stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let _ = path.as_ref();
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Host literal (tensor value).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn scalar(_v: f32) -> Self {
        Literal(())
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Self> {
        Err(Error::stub("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }
}

/// Device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b(&self, _args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client/device handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn platform_version(&self) -> String {
        "offline".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::stub("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_with_stub_message() {
        assert!(PjRtClient::cpu().unwrap_err().to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("/x").is_err());
        assert!(Literal::scalar(1.0).to_tuple().is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
    }
}
