//! `bfast gateway` acceptance suite — the resident fleet coordinator
//! over real loopback sockets. The contract under test: the gateway is
//! a drop-in `/v1` facade whose answers are **bit-identical** to a
//! direct single-process `BfastRunner::run` of the same scene, no
//! matter how the fleet behaves — N-worker fan-out, a worker murdered
//! mid-run (the shard re-splits onto survivors), operator-pinned
//! placement weights, and a randomized seeded kill schedule. A fleet
//! with no live workers fails a run with a typed error (never a hang),
//! and a cancel at the gateway DELETE-fans-out to every live shard.

use bfast::api::{AnalysisRequest, ParamSpec, SceneSource};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::gateway::chaos::{ChaosProxy, Mode};
use bfast::gateway::{Gateway, GatewayConfig};
use bfast::json;
use bfast::params::BfastParams;
use bfast::raster::{io as rio, BreakMap, TimeStack};
use bfast::serve::http::roundtrip;
use bfast::serve::{ServeConfig, Server};
use bfast::synth::ArtificialDataset;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Analysis shape shared by every test: N=48, n=36, h=12, k=1.
const PQ: &str = "?n-hist=36&h=12&k=1&freq=12&alpha=0.05";

fn params_new(n_total: usize) -> BfastParams {
    BfastParams::new(n_total, 36, 12, 1, 12.0, 0.05).unwrap()
}

fn param_spec() -> ParamSpec {
    ParamSpec {
        n_total: Some(48),
        n_hist: 36,
        h: 12,
        k: 1,
        freq: 12.0,
        alpha: 0.05,
        lambda: None,
    }
}

fn scene(m: usize, seed: u64) -> TimeStack {
    let mut data = ArtificialDataset::new(params_new(48), m, seed).generate();
    if m >= 8 {
        let d = data.stack.data_mut();
        for t in 0..48 {
            d[t * m] = f32::NAN; // dead pixel
        }
        for t in 10..14 {
            d[t * m + 3] = f32::NAN; // cloud hole
        }
    }
    data.stack
}

fn reference_map(stack: &TimeStack) -> BreakMap {
    BfastRunner::emulated(RunnerConfig::default())
        .unwrap()
        .run(stack, &params_new(48))
        .unwrap()
        .map
}

fn assert_maps_identical(a: &BreakMap, b: &BreakMap, ctx: &str) {
    assert_eq!(a.breaks, b.breaks, "{ctx}: breaks differ");
    assert_eq!(a.first, b.first, "{ctx}: first differ");
    assert_eq!(a.momax.len(), b.momax.len(), "{ctx}: momax length");
    for (px, (x, y)) in a.momax.iter().zip(&b.momax).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: momax differs at px {px}: {x} vs {y}");
    }
}

fn get(addr: &str, path: &str) -> (u16, Vec<u8>) {
    roundtrip(addr, "GET", path, "", &[]).unwrap()
}

fn parse_json(body: &[u8]) -> json::Value {
    json::parse(std::str::from_utf8(body).unwrap().trim()).unwrap()
}

fn parse_map(body: &[u8]) -> BreakMap {
    let v = parse_json(body);
    let ints = |key: &str| -> Vec<i32> {
        v.get(key)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect()
    };
    let momax = v
        .get("momax")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect();
    BreakMap { breaks: ints("breaks"), first: ints("first"), momax }
}

/// A worker; `gateway` = self-register and heartbeat there.
fn start_worker(gateway: Option<&str>) -> Server {
    Server::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        gateway: gateway.map(|s| s.to_string()),
        heartbeat: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap()
}

/// Fast-paced gateway defaults for tests; individual tests override
/// the failure-detection knobs they pin.
fn gw_cfg() -> GatewayConfig {
    GatewayConfig {
        addr: "127.0.0.1:0".into(),
        poll: Duration::from_millis(5),
        sweep: Duration::from_millis(50),
        ..Default::default()
    }
}

fn submit_json(gw: &str, req: &AnalysisRequest) -> u64 {
    let (status, body) =
        roundtrip(gw, "POST", "/v1/runs", "application/json", req.to_json_string().as_bytes())
            .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    parse_json(&body).get("job").unwrap().as_usize().unwrap() as u64
}

fn submit_bin(gw: &str, stack: &TimeStack) -> u64 {
    let (status, body) = roundtrip(
        gw,
        "POST",
        &format!("/v1/runs{PQ}"),
        "application/octet-stream",
        &rio::stack_to_bytes(stack),
    )
    .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    parse_json(&body).get("job").unwrap().as_usize().unwrap() as u64
}

/// Poll the gateway until the job reaches a terminal state.
fn wait_finished(gw: &str, id: u64, deadline: Duration) -> json::Value {
    let t0 = Instant::now();
    loop {
        let (status, body) = get(gw, &format!("/v1/runs/{id}"));
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let v = parse_json(&body);
        let s = v.get("status").unwrap().as_str().unwrap();
        if s == "done" || s == "failed" || s == "cancelled" {
            return v;
        }
        assert!(
            t0.elapsed() < deadline,
            "job {id} still {s} after {deadline:?} — the gateway hung"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn wait_alive(gw: &str, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, body) = get(gw, "/healthz");
        assert_eq!(status, 200);
        if parse_json(&body).get("workers_alive").unwrap().as_usize().unwrap() == want {
            return;
        }
        assert!(Instant::now() < deadline, "fleet never reached {want} live worker(s)");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn gw_metric(gw: &str, name: &str) -> u64 {
    let (status, body) = get(gw, "/metrics");
    assert_eq!(status, 200);
    String::from_utf8(body)
        .unwrap()
        .lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.trim().parse().ok()))
        .unwrap_or_else(|| panic!("metric {name} missing"))
}

/// Block until some job on this worker is running with ≥ 1 chunk done,
/// so a subsequent fault provably interrupts in-flight work.
fn observe_mid_run(worker: &str) {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let (status, body) = get(worker, "/v1/runs");
        assert_eq!(status, 200);
        let mid = parse_json(&body).get("jobs").unwrap().as_arr().unwrap().iter().any(|j| {
            j.get("status").unwrap().as_str().unwrap() == "running"
                && j.get("progress").unwrap().as_f64().unwrap() > 0.0
        });
        if mid {
            return;
        }
        assert!(Instant::now() < deadline, "{worker}: no shard reached mid-run");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn shard_entries(done: &json::Value) -> Vec<(String, usize, usize, usize)> {
    done.get("shards")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| {
            (
                s.get("worker").unwrap().as_str().unwrap().to_string(),
                s.get("pixel_start").unwrap().as_usize().unwrap(),
                s.get("pixel_end").unwrap().as_usize().unwrap(),
                s.get("attempts").unwrap().as_usize().unwrap(),
            )
        })
        .collect()
}

/// Acceptance: three self-registering workers carry one gateway run —
/// split evenly (no throughput observed yet), every worker used once,
/// the served map bit-identical to a direct run, zero rebalances.
#[test]
fn three_worker_fanout_is_bit_identical_to_direct_run() {
    let gw = Gateway::start(gw_cfg()).unwrap();
    let gaddr = gw.addr().to_string();
    let workers: Vec<Server> = (0..3).map(|_| start_worker(Some(&gaddr))).collect();
    wait_alive(&gaddr, 3);

    let stack = scene(257, 31);
    let reference = reference_map(&stack);
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = param_spec();
    let id = submit_json(&gaddr, &req);
    let done = wait_finished(&gaddr, id, Duration::from_secs(120));
    assert_eq!(
        done.get("status").unwrap().as_str().unwrap(),
        "done",
        "{}",
        done.to_string_compact()
    );
    assert_eq!(done.get("pixels").unwrap().as_usize().unwrap(), 257);

    let shards = shard_entries(&done);
    assert_eq!(shards.len(), 3, "{}", done.to_string_compact());
    let mut placed: Vec<&str> = shards.iter().map(|(w, ..)| w.as_str()).collect();
    placed.sort_unstable();
    let mut expected: Vec<String> = workers.iter().map(|w| w.addr().to_string()).collect();
    expected.sort();
    assert_eq!(placed, expected.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    assert!(shards.iter().all(|&(_, _, _, attempts)| attempts == 1));
    // an unobserved fleet splits evenly (largest-remainder over equal
    // fallback weights): 257 → 86 + 86 + 85
    let mut widths: Vec<usize> = shards.iter().map(|&(_, a, b, _)| b - a).collect();
    widths.sort_unstable();
    assert_eq!(widths, vec![85, 86, 86]);

    let (status, body) = get(&gaddr, &format!("/v1/runs/{id}/map"));
    assert_eq!(status, 200);
    assert_maps_identical(&parse_map(&body), &reference, "gateway fan-out vs direct");
    let (status, _) = get(&gaddr, &format!("/v1/runs/{id}/result"));
    assert_eq!(status, 200, "the typed result document is served too");
    assert_eq!(gw_metric(&gaddr, "bfast_gateway_rebalances_total"), 0);

    gw.stop().unwrap();
    for w in workers {
        w.stop().unwrap();
    }
}

/// Acceptance (the tentpole): a worker killed mid-run — observed
/// executing chunks, then its link severed — is buried, its shard
/// re-split onto the survivor, and the merged map is **still
/// bit-identical** to the single-process run.
#[test]
fn worker_killed_mid_run_rebalances_onto_survivors() {
    let w1 = start_worker(None);
    let w2 = start_worker(None);
    let proxy = ChaosProxy::start(&w2.addr().to_string()).unwrap();
    let mut cfg = gw_cfg();
    cfg.workers = vec![w1.addr().to_string(), proxy.addr().to_string()];
    cfg.io_timeout = Duration::from_millis(500);
    cfg.heartbeat_timeout = Duration::from_secs(2);
    let gw = Gateway::start(cfg).unwrap();
    let gaddr = gw.addr().to_string();
    wait_alive(&gaddr, 2);

    let stack = scene(100_000, 3);
    let reference = reference_map(&stack);
    let id = submit_bin(&gaddr, &stack);
    // wait until w2 is provably executing its shard, then murder the
    // link: new connections refused, the live poll socket severed
    observe_mid_run(&w2.addr().to_string());
    proxy.set_mode(Mode::Drop);
    proxy.kill_connections();

    let done = wait_finished(&gaddr, id, Duration::from_secs(300));
    assert_eq!(
        done.get("status").unwrap().as_str().unwrap(),
        "done",
        "{}",
        done.to_string_compact()
    );
    assert!(
        gw_metric(&gaddr, "bfast_gateway_rebalances_total") >= 1,
        "the mid-run death must be handled as a rebalance"
    );
    let shards = shard_entries(&done);
    let w1_addr = w1.addr().to_string();
    assert!(
        shards.iter().all(|(w, ..)| *w == w1_addr),
        "every credited shard must be on the survivor: {shards:?}"
    );
    assert!(
        shards.iter().any(|&(_, _, _, attempts)| attempts >= 2),
        "the rescued range must show a re-placement: {shards:?}"
    );
    let covered: usize = shards.iter().map(|&(_, a, b, _)| b - a).sum();
    assert_eq!(covered, 100_000, "no pixel may be lost or doubled: {shards:?}");

    let (status, body) = get(&gaddr, &format!("/v1/runs/{id}/map"));
    assert_eq!(status, 200);
    assert_maps_identical(&parse_map(&body), &reference, "rebalanced run vs direct");

    // the fleet view records the burial
    let paddr = proxy.addr().to_string();
    let (status, body) = get(&gaddr, "/v1/workers");
    assert_eq!(status, 200);
    let buried = parse_json(&body).get("workers").unwrap().as_arr().unwrap().iter().any(|w| {
        w.get("addr").unwrap().as_str().unwrap() == paddr
            && !w.get("alive").unwrap().as_bool().unwrap()
    });
    assert!(buried, "the dead worker must show as not alive");

    gw.stop().unwrap();
    proxy.stop();
    w1.stop().unwrap();
    w2.stop().unwrap();
}

/// Acceptance: a fleet whose every worker is dead fails the run with
/// the typed "no live workers" error, promptly — never a hang.
#[test]
fn dead_fleet_fails_with_typed_error_not_a_hang() {
    // a dead address: bind an ephemeral port, then drop the listener
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let mut cfg = gw_cfg();
    cfg.workers = vec![dead];
    cfg.io_timeout = Duration::from_millis(300);
    let gw = Gateway::start(cfg).unwrap();
    let gaddr = gw.addr().to_string();

    let mut req = AnalysisRequest::new(SceneSource::Inline(scene(64, 9)));
    req.params = param_spec();
    let t0 = Instant::now();
    let id = submit_json(&gaddr, &req);
    let done = wait_finished(&gaddr, id, Duration::from_secs(10));
    assert!(t0.elapsed() < Duration::from_secs(10));
    assert_eq!(
        done.get("status").unwrap().as_str().unwrap(),
        "failed",
        "{}",
        done.to_string_compact()
    );
    let error = done.get("error").unwrap().as_str().unwrap().to_string();
    assert!(error.contains("no live workers"), "untyped failure: {error}");
    // the map is refused with a 409, not served, not hung
    let (status, _) = get(&gaddr, &format!("/v1/runs/{id}/map"));
    assert_eq!(status, 409);
    gw.stop().unwrap();
}

/// Acceptance: cancelling at the gateway DELETE-fans-out to every live
/// shard — both workers' jobs land in `cancelled`, never `done`.
#[test]
fn cancel_fans_out_to_every_live_shard() {
    let w1 = start_worker(None);
    let w2 = start_worker(None);
    let mut cfg = gw_cfg();
    cfg.workers = vec![w1.addr().to_string(), w2.addr().to_string()];
    let gw = Gateway::start(cfg).unwrap();
    let gaddr = gw.addr().to_string();
    wait_alive(&gaddr, 2);

    let id = submit_bin(&gaddr, &scene(100_000, 3));
    // both shards provably mid-run, then pull the plug at the gateway
    observe_mid_run(&w1.addr().to_string());
    observe_mid_run(&w2.addr().to_string());
    let (status, body) = roundtrip(&gaddr, "DELETE", &format!("/v1/runs/{id}"), "", &[]).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    assert_eq!(parse_json(&body).get("status").unwrap().as_str().unwrap(), "cancelling");

    let done = wait_finished(&gaddr, id, Duration::from_secs(60));
    assert_eq!(
        done.get("status").unwrap().as_str().unwrap(),
        "cancelled",
        "{}",
        done.to_string_compact()
    );

    for addr in [w1.addr().to_string(), w2.addr().to_string()] {
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let (status, body) = get(&addr, "/v1/runs");
            assert_eq!(status, 200);
            let v = parse_json(&body);
            let jobs = v.get("jobs").unwrap().as_arr().unwrap();
            assert!(!jobs.is_empty(), "{addr}: shard was never submitted");
            let states: Vec<&str> = jobs
                .iter()
                .map(|j| j.get("status").unwrap().as_str().unwrap())
                .collect();
            assert!(
                !states.contains(&"done"),
                "{addr}: a shard ran to completion despite the cancel ({states:?})"
            );
            if states.iter().all(|s| *s == "cancelled") {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "{addr}: jobs never reached cancelled ({states:?})"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    // a cancelled run's result is a 409 at the facade
    let (status, _) = get(&gaddr, &format!("/v1/runs/{id}/result"));
    assert_eq!(status, 409);
    gw.stop().unwrap();
    w1.stop().unwrap();
    w2.stop().unwrap();
}

/// Satellite: operator-pinned weights steer the split — a 3:1 fleet
/// gives the heavy worker exactly 3/4 of the pixels, and the merged
/// map is unchanged down to the bits.
#[test]
fn pinned_weights_apportion_the_split() {
    let wa = start_worker(None);
    let wb = start_worker(None);
    let mut cfg = gw_cfg();
    // registered once below, no heartbeats: keep them alive all test
    cfg.heartbeat_timeout = Duration::from_secs(120);
    let gw = Gateway::start(cfg).unwrap();
    let gaddr = gw.addr().to_string();
    for (w, weight) in [(&wa, 3.0), (&wb, 1.0)] {
        let body = format!("{{\"addr\": \"{}\", \"weight\": {weight}}}", w.addr());
        let (status, resp) =
            roundtrip(&gaddr, "POST", "/v1/workers", "application/json", body.as_bytes()).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    }
    wait_alive(&gaddr, 2);

    let stack = scene(400, 17);
    let reference = reference_map(&stack);
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = param_spec();
    let id = submit_json(&gaddr, &req);
    let done = wait_finished(&gaddr, id, Duration::from_secs(120));
    assert_eq!(
        done.get("status").unwrap().as_str().unwrap(),
        "done",
        "{}",
        done.to_string_compact()
    );

    let mut widths: BTreeMap<String, usize> = BTreeMap::new();
    for (w, a, b, _) in shard_entries(&done) {
        *widths.entry(w).or_insert(0) += b - a;
    }
    assert_eq!(widths.get(&wa.addr().to_string()), Some(&300), "{widths:?}");
    assert_eq!(widths.get(&wb.addr().to_string()), Some(&100), "{widths:?}");

    let (status, body) = get(&gaddr, &format!("/v1/runs/{id}/map"));
    assert_eq!(status, 200);
    assert_maps_identical(&parse_map(&body), &reference, "weighted split vs direct");

    // the fleet view reports the pinned weights back
    let (status, body) = get(&gaddr, "/v1/workers");
    assert_eq!(status, 200);
    let mut weights: Vec<f64> = parse_json(&body)
        .get("workers")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|w| w.get("weight").unwrap().as_f64().unwrap())
        .collect();
    weights.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert_eq!(weights, vec![1.0, 3.0]);

    gw.stop().unwrap();
    wa.stop().unwrap();
    wb.stop().unwrap();
}

/// Seeded splitmix-style generator: the kill schedules below are
/// reproducible from the test source alone.
struct Lcg(u64);

impl Lcg {
    fn next_u64(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

/// Soak: for k ∈ {2, 3, 5} workers, murder a seeded-random subset
/// (always leaving ≥ 1 survivor) at seeded-random delays after
/// submit. Whatever the schedule does to the fleet, the merged map
/// equals the single-process run bit-for-bit.
#[test]
fn soak_random_kill_schedules_preserve_bit_identity() {
    let stack = scene(40_000, 11);
    let reference = reference_map(&stack);
    let bytes = rio::stack_to_bytes(&stack);
    for k in [2usize, 3, 5] {
        let mut rng = Lcg(0x5EED_0000 + k as u64);
        let workers: Vec<Server> = (0..k).map(|_| start_worker(None)).collect();
        let proxies: Vec<ChaosProxy> = workers
            .iter()
            .map(|w| ChaosProxy::start(&w.addr().to_string()).unwrap())
            .collect();
        let mut cfg = gw_cfg();
        cfg.workers = proxies.iter().map(|p| p.addr().to_string()).collect();
        cfg.io_timeout = Duration::from_millis(400);
        cfg.heartbeat_timeout = Duration::from_secs(2);
        let gw = Gateway::start(cfg).unwrap();
        let gaddr = gw.addr().to_string();
        wait_alive(&gaddr, k);

        let (status, body) = roundtrip(
            &gaddr,
            "POST",
            &format!("/v1/runs{PQ}"),
            "application/octet-stream",
            &bytes,
        )
        .unwrap();
        assert_eq!(status, 202, "k={k}: {}", String::from_utf8_lossy(&body));
        let id = parse_json(&body).get("job").unwrap().as_usize().unwrap() as u64;

        // pick 0..k-1 victims in seeded-shuffled order, each killed
        // after a seeded delay (a kill landing after completion is a
        // legal schedule and trivially preserves the property)
        let victims = (rng.next_u64() as usize) % k;
        let mut order: Vec<usize> = (0..k).collect();
        for i in (1..order.len()).rev() {
            order.swap(i, (rng.next_u64() as usize) % (i + 1));
        }
        for &v in order.iter().take(victims) {
            std::thread::sleep(Duration::from_millis(rng.next_u64() % 150));
            proxies[v].set_mode(Mode::Drop);
            proxies[v].kill_connections();
        }

        let done = wait_finished(&gaddr, id, Duration::from_secs(300));
        assert_eq!(
            done.get("status").unwrap().as_str().unwrap(),
            "done",
            "k={k} victims={victims}: {}",
            done.to_string_compact()
        );
        let (status, body) = get(&gaddr, &format!("/v1/runs/{id}/map"));
        assert_eq!(status, 200, "k={k}");
        assert_maps_identical(&parse_map(&body), &reference, &format!("k={k} victims={victims}"));

        gw.stop().unwrap();
        for p in proxies {
            p.stop();
        }
        for w in workers {
            w.stop().unwrap();
        }
    }
}
