//! Integration tests over the coordinated executor pipeline.
//!
//! The default build exercises the pure-rust [`EmulatedDevice`]
//! backend — the coordinator's staging/chunking/assembly must agree
//! with the scene-wide CPU implementations on every workload shape, in
//! both fused and phased modes, with no artifacts and no network.
//! The PJRT artifact tests live in the `pjrt_artifacts` module at the
//! bottom (feature `pjrt` + `make artifacts`).

use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::runtime::EmulatedDevice;
use bfast::synth::{ArtificialDataset, ChileScene};

fn agree(a: &[i32], b: &[i32]) -> f64 {
    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len().max(1) as f64
}

#[test]
fn fused_emulated_equals_cpu_on_synthetic() {
    let params = BfastParams::paper_synthetic();
    // m chosen to exercise multiple chunks + a padded tail (default
    // emulated contract has m_chunk = 1024)
    let data = ArtificialDataset::new(params.clone(), 2500, 17).generate();
    let runner = BfastRunner::emulated(RunnerConfig {
        artifact: Some("small".into()),
        ..Default::default()
    })
    .unwrap();
    let res = runner.run(&data.stack, &params).unwrap();
    assert_eq!(res.chunks, 3); // 1024+1024+452(padded)
    assert_eq!(res.artifact, "small");
    let (cpu_map, _) = FusedCpuBfast::new(params.clone(), &data.stack.time_axis)
        .unwrap()
        .run(&data.stack)
        .unwrap();
    assert_eq!(res.map.breaks, cpu_map.breaks, "break maps must agree exactly");
    assert_eq!(res.map.first, cpu_map.first, "first indices must agree");
    for (a, b) in res.map.momax.iter().zip(&cpu_map.momax) {
        assert!((a - b).abs() / b.abs().max(1.0) < 5e-3, "momax {a} vs {b}");
    }
}

#[test]
fn phased_equals_fused_emulated() {
    let params = BfastParams::paper_synthetic();
    let data = ArtificialDataset::new(params.clone(), 1500, 3).generate();
    let mut fused = BfastRunner::emulated(RunnerConfig::default()).unwrap();
    let mut phased =
        BfastRunner::emulated(RunnerConfig { phased: true, ..Default::default() }).unwrap();
    let rf = fused.run(&data.stack, &params).unwrap();
    let rp = phased.run(&data.stack, &params).unwrap();
    assert_eq!(rf.map.breaks, rp.map.breaks);
    assert_eq!(rf.map.first, rp.map.first);
    // phased mode must have recorded the paper's phase names
    for ph in ["transfer", "create model", "predictions", "mosum", "detect breaks"] {
        assert!(rp.phases.get(ph).is_some(), "missing phase {ph:?}");
    }
    // fused mode records the production phases
    for ph in ["transfer", "fused execute", "readback"] {
        assert!(rf.phases.get(ph).is_some(), "missing phase {ph:?}");
    }
}

#[test]
fn custom_chunk_width_changes_plan_not_results() {
    let params = BfastParams::paper_synthetic();
    let data = ArtificialDataset::new(params.clone(), 700, 11).generate();
    let run_mc = |mc: usize| {
        let backend = Box::new(EmulatedDevice::new().with_m_chunk(mc));
        let r = BfastRunner::new(backend, RunnerConfig::default()).unwrap();
        r.run(&data.stack, &params).unwrap()
    };
    let a = run_mc(256); // 3 chunks
    let b = run_mc(1024); // 1 chunk
    assert_eq!(a.chunks, 3);
    assert_eq!(b.chunks, 1);
    assert_eq!(a.map.breaks, b.map.breaks);
    assert_eq!(a.map.first, b.map.first);
    assert_eq!(a.map.momax, b.map.momax);
}

#[test]
fn chile_scene_irregular_axis() {
    let scene = ChileScene::scaled(48, 40, 23);
    let params = scene.params();
    let (stack, _) = scene.generate();
    let runner = BfastRunner::emulated(RunnerConfig {
        artifact: Some("chile".into()),
        ..Default::default()
    })
    .unwrap();
    let res = runner.run(&stack, &params).unwrap();
    let (cpu_map, _) = FusedCpuBfast::new(params.clone(), &stack.time_axis)
        .unwrap()
        .run(&stack)
        .unwrap();
    // Irregular axis + strong injected events: near-total agreement
    // (the emulator sees the f32-rounded axis, CPU the f64 one —
    // borderline pixels allowed at the margin).
    let rate = agree(&res.map.breaks, &cpu_map.breaks);
    assert!(rate > 0.995, "chile agreement {rate}");
    assert!(res.map.break_fraction() > 0.95, "paper: >99% breaks");
}

#[test]
fn queue_depth_and_threads_do_not_change_results() {
    let params = BfastParams::paper_synthetic();
    let data = ArtificialDataset::new(params.clone(), 3100, 9).generate();
    let mut outs = Vec::new();
    for (depth, threads) in [(1, 1), (2, 2), (4, 3)] {
        let runner = BfastRunner::emulated(RunnerConfig {
            queue_depth: depth,
            staging_threads: threads,
            ..Default::default()
        })
        .unwrap();
        outs.push(runner.run(&data.stack, &params).unwrap());
    }
    for o in &outs[1..] {
        assert_eq!(o.map.breaks, outs[0].map.breaks);
        assert_eq!(o.map.first, outs[0].map.first);
        assert_eq!(o.map.momax, outs[0].map.momax);
    }
}

#[test]
fn single_pixel_and_exact_chunk_sizes() {
    let params = BfastParams::paper_synthetic();
    let runner = BfastRunner::emulated(RunnerConfig::default()).unwrap();
    for m in [1usize, 1023, 1024, 1025, 2048] {
        let data = ArtificialDataset::new(params.clone(), m, 31).generate();
        let res = runner.run(&data.stack, &params).unwrap();
        assert_eq!(res.len(), m, "m={m}");
        let (cpu_map, _) = FusedCpuBfast::new(params.clone(), &data.stack.time_axis)
            .unwrap()
            .run(&data.stack)
            .unwrap();
        assert_eq!(res.map.breaks, cpu_map.breaks, "m={m}");
    }
}

#[test]
fn empty_scene_runs_clean() {
    let params = BfastParams::paper_synthetic();
    let runner = BfastRunner::emulated(RunnerConfig::default()).unwrap();
    let stack = bfast::raster::TimeStack::zeros(params.n_total, 0);
    let res = runner.run(&stack, &params).unwrap();
    assert_eq!(res.chunks, 0);
    assert!(res.is_empty());
}

#[test]
fn missing_values_filled_in_staging() {
    let params = BfastParams::paper_synthetic();
    let data = ArtificialDataset::new(params.clone(), 600, 77).generate();
    // punch NaN holes, keeping first/last layers intact for fill
    let mut holey = data.stack.clone();
    let m = holey.n_pixels();
    for px in (0..m).step_by(7) {
        let t = 1 + px % (params.n_total - 2);
        holey.data_mut()[t * m + px] = f32::NAN;
    }
    let runner = BfastRunner::emulated(RunnerConfig::default()).unwrap();
    let res = runner.run(&holey, &params).unwrap();
    // host-side fill then run must give identical results
    let mut prefilled = holey.clone();
    bfast::fill::fill_stack(&mut prefilled, 4);
    let res2 = runner.run(&prefilled, &params).unwrap();
    assert_eq!(res.map.breaks, res2.map.breaks);
    assert_eq!(res.map.momax, res2.map.momax);
}

#[test]
fn wrong_shape_params_are_rejected_by_pinned_backend() {
    // A backend pinned to one contract shape (like a real AOT
    // artifact) must reject analyses with a different shape.
    let backend = Box::new(EmulatedDevice::new().with_shape(200, 100, 50, 3));
    let runner = BfastRunner::new(backend, RunnerConfig::default()).unwrap();
    let params = BfastParams::new(100, 50, 25, 3, 23.0, 0.05).unwrap();
    let stack = bfast::raster::TimeStack::zeros(100, 10);
    let err = runner.run(&stack, &params).unwrap_err().to_string();
    assert!(err.contains("shaped"), "{err}");
}

#[test]
fn layer_mismatch_rejected() {
    let params = BfastParams::paper_synthetic();
    let runner = BfastRunner::emulated(RunnerConfig::default()).unwrap();
    let stack = bfast::raster::TimeStack::zeros(10, 4);
    assert!(runner.run(&stack, &params).is_err());
}

/// Artifact-backed PJRT tests (need `--features pjrt` + `make
/// artifacts`; skip silently when the manifest is absent).
#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("SKIP device tests: run `make artifacts` first");
            None
        }
    }

    #[test]
    fn fused_device_equals_cpu_on_synthetic() {
        let Some(dir) = artifacts() else { return };
        let params = BfastParams::paper_synthetic();
        let data = ArtificialDataset::new(params.clone(), 2500, 17).generate();
        let runner = BfastRunner::from_manifest_dir(
            &dir,
            RunnerConfig { artifact: Some("small".into()), ..Default::default() },
        )
        .unwrap();
        let res = runner.run(&data.stack, &params).unwrap();
        let (cpu_map, _) = FusedCpuBfast::new(params.clone(), &data.stack.time_axis)
            .unwrap()
            .run(&data.stack)
            .unwrap();
        assert_eq!(res.map.breaks, cpu_map.breaks);
        assert_eq!(res.map.first, cpu_map.first);
    }

    #[test]
    fn pallas_and_xla_variants_agree() {
        let Some(dir) = artifacts() else { return };
        let params = BfastParams::paper_synthetic();
        let data = ArtificialDataset::new(params.clone(), 900, 5).generate();
        let run = |name: &str| {
            let r = BfastRunner::from_manifest_dir(
                &dir,
                RunnerConfig { artifact: Some(name.into()), ..Default::default() },
            )
            .unwrap();
            r.run(&data.stack, &params).unwrap()
        };
        let a = run("default"); // pallas kernel
        let b = run("default_xla"); // plain-XLA ablation
        assert_eq!(a.map.breaks, b.map.breaks);
        assert_eq!(a.map.first, b.map.first);
    }

    #[test]
    fn phased_device_equals_fused_device() {
        let Some(dir) = artifacts() else { return };
        let params = BfastParams::paper_synthetic();
        let data = ArtificialDataset::new(params.clone(), 1500, 3).generate();
        let mut fused = BfastRunner::from_manifest_dir(
            &dir,
            RunnerConfig { artifact: Some("small".into()), ..Default::default() },
        )
        .unwrap();
        let mut phased = BfastRunner::from_manifest_dir(
            &dir,
            RunnerConfig { artifact: Some("small".into()), phased: true, ..Default::default() },
        )
        .unwrap();
        let rf = fused.run(&data.stack, &params).unwrap();
        let rp = phased.run(&data.stack, &params).unwrap();
        assert_eq!(rf.map.breaks, rp.map.breaks);
        assert_eq!(rf.map.first, rp.map.first);
    }
}
