//! Monitor-session equivalence suite: ingesting layers `n+1..=N` one
//! at a time must reproduce a fresh coordinated `BfastRunner::run`
//! **bit-identically at every prefix length** — on clean scenes and on
//! gappy ones (cloud holes, leading gaps, dead pixels, and a pixel
//! whose first valid observation only arrives mid-monitoring). Plus:
//! save/resume exactness and the defined all-NaN no-break contract
//! across every engine.

use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::monitor::{MonitorConfig, MonitorSession};
use bfast::params::BfastParams;
use bfast::pixel::{DirectBfast, NaiveBfast};
use bfast::prng::Pcg32;
use bfast::raster::{BreakMap, TimeStack};
use bfast::runtime::EmulatedDevice;
use bfast::synth::ArtificialDataset;

fn base_params() -> BfastParams {
    // N = 52 total; sessions prime at 41 and ingest the remaining 11
    BfastParams::with_lambda(52, 40, 16, 2, 12.0, 0.05, 2.5).unwrap()
}

fn params_at(base: &BfastParams, n_total: usize) -> BfastParams {
    BfastParams::with_lambda(
        n_total,
        base.n_hist,
        base.h,
        base.k,
        base.freq,
        base.alpha,
        base.lambda,
    )
    .unwrap()
}

/// Fresh coordinated run over a prefix of the archive.
fn fresh_map(stack: &TimeStack, params: &BfastParams, m_chunk: usize) -> BreakMap {
    let backend = EmulatedDevice::new().with_m_chunk(m_chunk);
    let runner =
        BfastRunner::new(Box::new(backend), RunnerConfig::default()).unwrap();
    runner.run(stack, params).unwrap().map
}

/// Bitwise break-map equality (momax compared as bits so that
/// identically-NaN statistics also count as equal).
fn assert_maps_identical(a: &BreakMap, b: &BreakMap, ctx: &str) {
    assert_eq!(a.breaks, b.breaks, "{ctx}: breaks differ");
    assert_eq!(a.first, b.first, "{ctx}: first differ");
    assert_eq!(a.momax.len(), b.momax.len(), "{ctx}: momax length");
    for (px, (x, y)) in a.momax.iter().zip(&b.momax).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: momax differs at px {px}: {x} vs {y}"
        );
    }
}

/// Session primed on the first `n0` layers, then fed layer by layer;
/// after each ingest the break map must equal a fresh coordinated run
/// over the same prefix, bit for bit.
fn check_prefix_equivalence(stack: &TimeStack, base: &BfastParams, n0: usize, ctx: &str) {
    let init = stack.prefix(n0).unwrap();
    let cfg = MonitorConfig { m_chunk: 32, threads: 3, fill_missing: true };
    let mut session = MonitorSession::start(&init, &params_at(base, n0), cfg).unwrap();
    assert_maps_identical(
        &session.break_map(),
        &fresh_map(&init, &params_at(base, n0), 64),
        &format!("{ctx}: prime at {n0}"),
    );
    let mut running = session.break_count();
    for nt in n0 + 1..=stack.n_times() {
        let delta = session
            .ingest(stack.time_axis[nt - 1], stack.layer(nt - 1))
            .unwrap();
        assert_eq!(delta.layer, nt - 1);
        assert_eq!(delta.monitor_index, nt - 1 - base.n_hist);
        let prefix = stack.prefix(nt).unwrap();
        assert_maps_identical(
            &session.break_map(),
            &fresh_map(&prefix, &params_at(base, nt), 64),
            &format!("{ctx}: prefix {nt}"),
        );
        // every break must be announced in exactly one delta — even
        // retroactive crossings revealed by a late-reporting pixel
        running += delta.new_breaks.len();
        assert_eq!(delta.total_breaks, running, "{ctx}: delta accounting at {nt}");
        assert_eq!(delta.total_breaks, session.break_map().break_count());
    }
}

#[test]
fn clean_scene_ingest_equals_fresh_runs_at_every_prefix() {
    let base = base_params();
    let data = ArtificialDataset::new(base.clone(), 137, 21).generate();
    check_prefix_equivalence(&data.stack, &base, base.n_hist + 1, "clean");
}

/// Clean scene, but primed on a larger initial archive (mid-monitor).
#[test]
fn late_session_start_equals_fresh_runs() {
    let base = base_params();
    let data = ArtificialDataset::new(base.clone(), 77, 22).generate();
    check_prefix_equivalence(&data.stack, &base, 47, "late-start");
}

/// Gappy scene: random cloud holes, a leading gap, an entirely-dead
/// pixel and a pixel that only starts reporting mid-monitoring (its
/// backfilled history must be rebuilt exactly).
fn gappy_scene(base: &BfastParams, m: usize, seed: u64) -> TimeStack {
    let mut data = ArtificialDataset::new(base.clone(), m, seed).generate();
    let n_t = data.stack.n_times();
    let mut rng = Pcg32::with_stream(seed, 0x6A77);
    {
        let d = data.stack.data_mut();
        // ~6% random holes on the first half of the pixels
        for px in 0..m / 2 {
            for t in 0..n_t {
                if rng.uniform() < 0.06 {
                    d[t * m + px] = f32::NAN;
                }
            }
        }
        // leading gap (backward fill inside the initial archive)
        for t in 0..6 {
            d[t * m + (m - 3)] = f32::NAN;
        }
        // dead pixel: never reports
        for t in 0..n_t {
            d[t * m + (m - 2)] = f32::NAN;
        }
        // late pixel: silent until layer 46 (0-based), then reports —
        // a fresh run backfills its whole history from that value
        for t in 0..46 {
            d[t * m + (m - 1)] = f32::NAN;
        }
    }
    data.stack
}

#[test]
fn gappy_scene_ingest_equals_fresh_runs_at_every_prefix() {
    let base = base_params();
    let stack = gappy_scene(&base, 90, 5);
    check_prefix_equivalence(&stack, &base, base.n_hist + 1, "gappy");
}

#[test]
fn gappy_scene_second_seed_still_equivalent() {
    let base = base_params();
    let stack = gappy_scene(&base, 61, 17);
    check_prefix_equivalence(&stack, &base, base.n_hist + 2, "gappy-2");
}

#[test]
fn save_resume_is_bit_exact_mid_stream() {
    let base = base_params();
    let stack = gappy_scene(&base, 53, 9);
    let n0 = base.n_hist + 1;
    let init = stack.prefix(n0).unwrap();
    let cfg = MonitorConfig { m_chunk: 16, threads: 2, fill_missing: true };
    let mut live = MonitorSession::start(&init, &params_at(&base, n0), cfg).unwrap();

    // advance both: `live` runs straight through; `resumed` is saved
    // and reloaded halfway
    let dir = std::env::temp_dir().join(format!("bfast_monresume_{}", std::process::id()));
    let split = 47;
    for nt in n0 + 1..=split {
        live.ingest(stack.time_axis[nt - 1], stack.layer(nt - 1)).unwrap();
    }
    live.save(&dir).unwrap();
    let mut resumed = MonitorSession::load(&dir, 4).unwrap();
    assert_eq!(resumed.n_seen(), split);
    for nt in split + 1..=stack.n_times() {
        let (t, layer) = (stack.time_axis[nt - 1], stack.layer(nt - 1));
        live.ingest(t, layer).unwrap();
        resumed.ingest(t, layer).unwrap();
        assert_maps_identical(
            &live.break_map(),
            &resumed.break_map(),
            &format!("resumed vs live at {nt}"),
        );
    }
    // and both equal the fresh run over the full archive
    assert_maps_identical(
        &live.break_map(),
        &fresh_map(&stack, &params_at(&base, stack.n_times()), 64),
        "resumed stream vs fresh full run",
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn streamed_synth_layers_drive_a_session_to_the_batch_answer() {
    // generator stream → ingest == generator batch → fresh run
    let base = base_params();
    let gen = ArtificialDataset::new(base.clone(), 64, 33);
    let data = gen.generate();
    let n0 = base.n_hist + 1;
    let init = data.stack.prefix(n0).unwrap();
    let mut session =
        MonitorSession::start(&init, &params_at(&base, n0), MonitorConfig::default())
            .unwrap();
    for (t, layer) in gen.stream().skip(n0) {
        session.ingest(t, &layer).unwrap();
    }
    assert_eq!(session.n_seen(), base.n_total);
    assert_maps_identical(
        &session.break_map(),
        &fresh_map(&data.stack, &base, 1024),
        "streamed ingest vs batch",
    );
}

#[test]
fn all_nan_pixel_yields_defined_no_break_through_every_engine() {
    // An entirely-missing series (fill leaves it NaN) must produce
    // breaks=0, first=-1, momax=0.0 — not NaN-poisoned output — in
    // every implementation, coordinated or not.
    let p = BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, 3.0).unwrap();
    let mut data = ArtificialDataset::new(p.clone(), 9, 3).generate();
    let dead = 4usize;
    for t in 0..48 {
        data.stack.data_mut()[t * 9 + dead] = f32::NAN;
    }
    let stack = &data.stack;

    let check = |label: &str, breaks: i32, first: i32, momax: f32| {
        assert_eq!(breaks, 0, "{label}: dead pixel flagged as break");
        assert_eq!(first, -1, "{label}: dead pixel has a first-crossing");
        assert!(momax.is_finite(), "{label}: momax poisoned: {momax}");
        assert_eq!(momax, 0.0, "{label}: momax should be 0, got {momax}");
    };

    let direct = DirectBfast::new(p.clone(), &stack.time_axis).unwrap().run(stack).unwrap();
    check("direct", direct.breaks[dead], direct.first[dead], direct.momax[dead]);

    let naive = NaiveBfast::new(p.clone()).run(stack).unwrap();
    check("naive", naive.breaks[dead], naive.first[dead], naive.momax[dead]);

    let (fused, _) = FusedCpuBfast::new(p.clone(), &stack.time_axis)
        .unwrap()
        .run(stack)
        .unwrap();
    check("fused cpu", fused.breaks[dead], fused.first[dead], fused.momax[dead]);

    let runner = BfastRunner::emulated(RunnerConfig::default()).unwrap();
    let res = runner.run(stack, &p).unwrap();
    check("emulated pipeline", res.map.breaks[dead], res.map.first[dead], res.map.momax[dead]);

    let session = MonitorSession::start(stack, &p, MonitorConfig::default()).unwrap();
    let map = session.break_map();
    check("monitor session", map.breaks[dead], map.first[dead], map.momax[dead]);

    // and the healthy pixels still carry finite statistics everywhere
    for px in 0..9 {
        if px != dead {
            assert!(res.map.momax[px].is_finite() && res.map.momax[px] > 0.0);
        }
    }
}
