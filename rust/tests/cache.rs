//! Result-cache + compressed-wire acceptance suite, over real loopback
//! sockets. The contract: resubmitting a byte-identical request is
//! answered from the content-addressed cache — born-done job record,
//! **bit-identical** result envelope, zero new worker traffic behind a
//! gateway — and a gzipped upload of the same scene hashes to the same
//! digest as its raw form (so it *hits* the entry the raw submit
//! filled). `DELETE /v1/cache` drops the entries and the next submit
//! is a miss again.

use bfast::gateway::{Gateway, GatewayConfig};
use bfast::json;
use bfast::params::BfastParams;
use bfast::raster::{io as rio, TimeStack};
use bfast::serve::http::{roundtrip, Client};
use bfast::serve::{ServeConfig, Server};
use bfast::store::gzip_compress;
use bfast::synth::ArtificialDataset;
use std::time::{Duration, Instant};

/// Analysis shape shared by every test: N=48, n=36, h=12, k=1.
const PQ: &str = "?n-hist=36&h=12&k=1&freq=12&alpha=0.05";

fn scene(m: usize, seed: u64) -> TimeStack {
    let params = BfastParams::new(48, 36, 12, 1, 12.0, 0.05).unwrap();
    ArtificialDataset::new(params, m, seed).generate().stack
}

fn get(addr: &str, path: &str) -> (u16, Vec<u8>) {
    roundtrip(addr, "GET", path, "", &[]).unwrap()
}

fn parse_json(body: &[u8]) -> json::Value {
    json::parse(std::str::from_utf8(body).unwrap().trim()).unwrap()
}

/// Submit `.bsq` bytes; returns (job id, parsed 202 body).
fn submit_bin(addr: &str, bytes: &[u8]) -> (u64, json::Value) {
    let (status, body) =
        roundtrip(addr, "POST", &format!("/v1/runs{PQ}"), "application/octet-stream", bytes)
            .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let v = parse_json(&body);
    (v.get("job").unwrap().as_usize().unwrap() as u64, v)
}

fn wait_done(addr: &str, id: u64) -> json::Value {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, body) = get(addr, &format!("/v1/runs/{id}"));
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let v = parse_json(&body);
        match v.get("status").unwrap().as_str().unwrap() {
            "done" => return v,
            "failed" => panic!("job {id} failed: {}", String::from_utf8_lossy(&body)),
            s => assert!(Instant::now() < deadline, "job {id} still {s} — hung"),
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn result_body(addr: &str, id: u64) -> Vec<u8> {
    let (status, body) = get(addr, &format!("/v1/runs/{id}/result"));
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    body
}

fn cache_stats(addr: &str) -> json::Value {
    let (status, body) = get(addr, "/v1/cache");
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    parse_json(&body)
}

/// Whether a job/submit JSON carries `"cached": true`.
fn is_cached(v: &json::Value) -> bool {
    v.get("cached").and_then(|c| c.as_bool()).unwrap_or(false)
}

/// The full serve-level contract on one server: miss → fill → hit
/// (bit-identical, ETag/304), gzip upload hits the raw entry, clear
/// makes the next submit a miss again.
#[test]
fn serve_cache_hit_is_bit_identical_and_gzip_upload_shares_the_digest() {
    let server =
        Server::start(ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() }).unwrap();
    let addr = server.addr().to_string();
    let bytes = rio::stack_to_bytes(&scene(64, 5));

    // first submit: a miss that computes and fills the cache
    let (id1, v1) = submit_bin(&addr, &bytes);
    assert!(!is_cached(&v1), "first submit must not be a cache hit");
    wait_done(&addr, id1);
    let envelope = result_body(&addr, id1);

    // second identical submit: born-done record, bit-identical envelope
    let (id2, v2) = submit_bin(&addr, &bytes);
    assert_ne!(id1, id2, "a cache hit still mints a fresh job id");
    assert!(is_cached(&v2), "identical resubmit must hit: {}", v2.to_string_compact());
    assert_eq!(v2.get("status").unwrap().as_str().unwrap(), "done");
    let status2 = wait_done(&addr, id2);
    assert!(is_cached(&status2), "job record must carry cached: true");
    assert_eq!(envelope, result_body(&addr, id2), "cache hit must be bit-identical");

    // the ETag is the request digest; If-None-Match turns the re-fetch
    // into a bodyless 304 on the SAME keep-alive socket
    let mut client = Client::connect(&addr).unwrap();
    let (status, headers, body) = client
        .request_with_headers("GET", &format!("/v1/runs/{id1}/result"), "", &[], &[])
        .unwrap();
    assert_eq!(status, 200);
    assert_eq!(body, envelope);
    let etag = headers
        .iter()
        .find(|(k, _)| k == "etag")
        .map(|(_, v)| v.clone())
        .expect("finished result must carry an ETag");
    assert!(
        etag.len() == 66 && etag.starts_with('"') && etag.ends_with('"'),
        "ETag must be the quoted 64-hex request digest, got {etag:?}"
    );
    let (status, _, body) = client
        .request_with_headers(
            "GET",
            &format!("/v1/runs/{id1}/result"),
            "",
            &[("If-None-Match", &etag)],
            &[],
        )
        .unwrap();
    assert_eq!(status, 304, "matching If-None-Match must 304");
    assert!(body.is_empty(), "a 304 carries no body");

    // a gzipped upload of the same scene sniffs, inflates, hashes to
    // the same scene digest — and therefore HITS the raw submit's entry
    let (id3, v3) = submit_bin(&addr, &gzip_compress(&bytes));
    assert!(is_cached(&v3), "gzip upload of the same scene must share the digest");
    assert_eq!(envelope, result_body(&addr, id3), "gzip-upload result must be bit-identical");
    let (status, headers, _) = client
        .request_with_headers("GET", &format!("/v1/runs/{id3}/result"), "", &[], &[])
        .unwrap();
    assert_eq!(status, 200);
    let etag3 = headers.iter().find(|(k, _)| k == "etag").map(|(_, v)| v.clone()).unwrap();
    assert_eq!(etag, etag3, "raw and gzipped uploads must share the request digest");

    // Content-Encoding: gzip on the request is decoded centrally and
    // behaves identically
    let (status, _, body) = client
        .request_with_headers(
            "POST",
            &format!("/v1/runs{PQ}"),
            "application/octet-stream",
            &[("Content-Encoding", "gzip")],
            &gzip_compress(&bytes),
        )
        .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    assert!(is_cached(&parse_json(&body)), "Content-Encoding path must hit too");

    let stats = cache_stats(&addr);
    assert!(stats.get("enabled").unwrap().as_bool().unwrap());
    assert!(stats.get("hits").unwrap().as_usize().unwrap() >= 3);
    assert!(stats.get("entries").unwrap().as_usize().unwrap() >= 1);
    assert!(stats.get("bytes").unwrap().as_usize().unwrap() > 0);
    let (status, body) = get(&addr, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    let hits: f64 = text
        .lines()
        .find_map(|l| l.strip_prefix("bfast_cache_hits_total "))
        .expect("bfast_cache_hits_total sample missing")
        .trim()
        .parse()
        .unwrap();
    assert!(hits >= 3.0, "exported hit counter lags the stats endpoint");

    // clear: the same request is a miss again (and recomputes fine)
    let (status, body) = roundtrip(&addr, "DELETE", "/v1/cache", "", &[]).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    assert!(parse_json(&body).get("cleared").unwrap().as_usize().unwrap() >= 1);
    let (id4, v4) = submit_bin(&addr, &bytes);
    assert!(!is_cached(&v4), "a cleared cache must miss");
    wait_done(&addr, id4);
    assert_eq!(envelope, result_body(&addr, id4), "recompute must match the cached bytes");

    server.stop().unwrap();
}

/// `--cache-cap 0` semantics: a disabled cache never hits and the
/// stats endpoint says so.
#[test]
fn disabled_cache_never_hits() {
    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        cache_cap: 0,
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().to_string();
    let bytes = rio::stack_to_bytes(&scene(48, 9));
    let (id1, _) = submit_bin(&addr, &bytes);
    wait_done(&addr, id1);
    let (id2, v2) = submit_bin(&addr, &bytes);
    assert!(!is_cached(&v2), "a disabled cache must never hit");
    wait_done(&addr, id2);
    let stats = cache_stats(&addr);
    assert!(!stats.get("enabled").unwrap().as_bool().unwrap());
    assert_eq!(stats.get("hits").unwrap().as_usize().unwrap(), 0);
    server.stop().unwrap();
}

fn worker_job_count(addr: &str) -> usize {
    let (status, body) = get(addr, "/v1/runs");
    assert_eq!(status, 200);
    parse_json(&body).get("jobs").unwrap().as_arr().unwrap().len()
}

fn wait_alive(gw: &str, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, body) = get(gw, "/healthz");
        assert_eq!(status, 200);
        if parse_json(&body).get("workers_alive").unwrap().as_usize().unwrap() == want {
            return;
        }
        assert!(Instant::now() < deadline, "fleet never reached {want} live worker(s)");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Gateway-level contract: a cache hit short-circuits placement — the
/// second identical submit creates **zero** new jobs on either worker
/// and still answers with the bit-identical merged envelope.
#[test]
fn gateway_cache_hit_creates_zero_worker_traffic() {
    let w1 =
        Server::start(ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() }).unwrap();
    let w2 =
        Server::start(ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() }).unwrap();
    let gw = Gateway::start(GatewayConfig {
        addr: "127.0.0.1:0".into(),
        workers: vec![w1.addr().to_string(), w2.addr().to_string()],
        poll: Duration::from_millis(5),
        sweep: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap();
    let gaddr = gw.addr().to_string();
    wait_alive(&gaddr, 2);

    let bytes = rio::stack_to_bytes(&scene(96, 21));
    let (id1, v1) = submit_bin(&gaddr, &bytes);
    assert!(!is_cached(&v1));
    wait_done(&gaddr, id1);
    let envelope = result_body(&gaddr, id1);

    let before = (
        worker_job_count(&w1.addr().to_string()),
        worker_job_count(&w2.addr().to_string()),
    );
    assert!(before.0 + before.1 >= 1, "the first run must have reached the fleet");

    let (id2, v2) = submit_bin(&gaddr, &bytes);
    assert!(is_cached(&v2), "identical resubmit must hit: {}", v2.to_string_compact());
    assert_eq!(v2.get("status").unwrap().as_str().unwrap(), "done");
    let status2 = wait_done(&gaddr, id2);
    assert!(is_cached(&status2));
    assert_eq!(envelope, result_body(&gaddr, id2), "gateway hit must be bit-identical");

    let after = (
        worker_job_count(&w1.addr().to_string()),
        worker_job_count(&w2.addr().to_string()),
    );
    assert_eq!(before, after, "a gateway cache hit must place zero worker jobs");

    let stats = cache_stats(&gaddr);
    assert!(stats.get("hits").unwrap().as_usize().unwrap() >= 1);

    gw.stop().unwrap();
    w1.stop().unwrap();
    w2.stop().unwrap();
}
