//! Known-answer vectors for the MOSUM kernels (`bfast::mosum`) plus
//! the NaN contracts the fused engine relies on. Fixtures use exactly
//! representable values (small integers, powers of two) so every
//! assertion can be **bitwise** — these pins are what lets the
//! optimised engine loops be rewritten without moving a single ulp.

use bfast::cpu::FusedCpuBfast;
use bfast::mosum::{
    boundary, boundary_at, mosum_process, rolling_step, scan_breaks, sigma_hat,
    window_matrix_f32, BreakScan,
};
use bfast::params::BfastParams;
use bfast::raster::TimeStack;
use bfast::synth::ArtificialDataset;

/// N=12, n=8, h=4, k=1 (p=4, dof=4, 4 monitor steps) — small enough
/// to hand-compute every window.
fn tiny() -> BfastParams {
    BfastParams::with_lambda(12, 8, 4, 1, 4.0, 0.05, 2.0).unwrap()
}

#[test]
fn mosum_process_hand_computed_integer_fixture() {
    let p = tiny();
    // residuals r_1..r_12 = 1..12 (exact in f64)
    let r: Vec<f64> = (1..=12).map(|v| v as f64).collect();

    // σ̂ = sqrt(Σ_{1..8} v² / dof) = sqrt(204/4)
    let want_sigma = (204.0f64 / 4.0).sqrt();
    assert_eq!(sigma_hat(&r, &p).to_bits(), want_sigma.to_bits());

    // window sums of h=4 ending at t=9..12: 6+7+8+9=30, then rolling
    // +10-6, +11-7, +12-8 → 34, 38, 42. All integers → the rolling
    // accumulator is exact and the only rounding is the final divide.
    let denom = want_sigma * 8.0f64.sqrt();
    let mo = mosum_process(&r, &p);
    assert_eq!(mo.len(), 4);
    for (got, want_sum) in mo.iter().zip([30.0f64, 34.0, 38.0, 42.0]) {
        assert_eq!(got.to_bits(), (want_sum / denom).to_bits());
    }
}

#[test]
fn rolling_step_binary_fixture_and_truncation() {
    // all powers of two: no rounding anywhere
    let mut acc = 1.5f64;
    let got = rolling_step(&mut acc, 2.0, 0.25, 0.5);
    assert_eq!(acc, 1.25);
    assert_eq!(got, 0.625f32);

    // the f64 accumulator absorbs f32 inputs exactly
    let mut acc = 0.0f64;
    let got = rolling_step(&mut acc, 1.0, 3.0, 1.0);
    assert_eq!(acc, 2.0);
    assert_eq!(got, 2.0f32);
}

#[test]
fn rolling_step_nan_poisons_the_accumulator_for_good() {
    let mut acc = 1.0f64;
    let got = rolling_step(&mut acc, 2.0, f32::NAN, 0.5);
    assert!(got.is_nan());
    assert!(acc.is_nan());
    // finite later updates cannot un-poison it — this is what makes a
    // NaN residual inside the ring suppress every later window
    let got = rolling_step(&mut acc, 2.0, 1.0, 1.0);
    assert!(got.is_nan() && acc.is_nan());
}

#[test]
fn boundary_at_pins_both_log_plus_branches() {
    let p = BfastParams::with_lambda(300, 100, 50, 3, 23.0, 0.05, 2.5).unwrap();
    // t/n ≤ e → log₊ = 1 → boundary is exactly λ
    assert_eq!(boundary_at(&p, 0).to_bits(), 2.5f64.to_bits());
    // t = 272 → t/n = 2.72 > e → λ·sqrt(ln(t/n))
    let want = 2.5 * (272.0f64 / 100.0).ln().sqrt();
    assert_eq!(boundary_at(&p, 171).to_bits(), want.to_bits());
    // the vector form shares the kernel bit-for-bit
    let b = boundary(&p);
    assert_eq!(b.len(), p.n_monitor());
    assert_eq!(b[0].to_bits(), boundary_at(&p, 0).to_bits());
    assert_eq!(b[171].to_bits(), boundary_at(&p, 171).to_bits());
}

#[test]
fn scan_breaks_known_vectors() {
    // crossing at index 1; momax from a non-crossing later value
    let s = scan_breaks(&[1.0, -3.0, 2.0, -3.5], &[2.0, 2.0, 4.0, 4.0]);
    assert_eq!(s, BreakScan { has_break: true, first: 1, momax: 3.5 });

    // touching the boundary is not a crossing (strict >)
    let s = scan_breaks(&[2.0], &[2.0]);
    assert_eq!(s, BreakScan { has_break: false, first: -1, momax: 2.0 });

    // empty monitor period
    let s = scan_breaks(&[], &[]);
    assert_eq!(s, BreakScan { has_break: false, first: -1, momax: 0.0 });
}

#[test]
fn scan_breaks_nan_never_crosses_or_scores() {
    // NaN compares false against both the boundary and the running
    // max, so a NaN-laden process can still break on its finite values
    let s = scan_breaks(&[f64::NAN, 3.0], &[2.0, 2.0]);
    assert_eq!(s, BreakScan { has_break: true, first: 1, momax: 3.0 });

    // ... and an all-NaN process reports no break at all
    let s = scan_breaks(&[f64::NAN, f64::NAN], &[2.0, 2.0]);
    assert_eq!(s, BreakScan { has_break: false, first: -1, momax: 0.0 });
}

#[test]
fn nan_inside_the_monitor_ring_suppresses_later_windows_only() {
    let p = tiny();
    let mut r: Vec<f64> = (1..=12).map(|v| v as f64).collect();
    r[10] = f64::NAN; // r_11, inside the monitor period
    let mo = mosum_process(&r, &p);
    // windows ending at t=9,10 predate the NaN
    assert!(mo[0].is_finite() && mo[1].is_finite());
    // every window containing r_11 is poisoned
    assert!(mo[2].is_nan() && mo[3].is_nan());
}

#[test]
fn all_nan_pixel_reports_no_break_end_to_end() {
    let p = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 2.5).unwrap();
    let data = ArtificialDataset::new(p.clone(), 64, 9).generate();
    let mut stack = TimeStack::from_vec(
        data.stack.n_times(),
        data.stack.n_pixels(),
        data.stack.data().to_vec(),
    )
    .unwrap();
    let m = stack.n_pixels();
    for t in 0..stack.n_times() {
        stack.layer_mut(t)[5] = f32::NAN; // pixel 5: nothing but gaps
    }
    let engine = FusedCpuBfast::new(p, &stack.time_axis).unwrap();
    let (map, _) = engine.run(&stack).unwrap();
    assert_eq!(map.breaks[5], 0, "all-NaN pixel must not break");
    assert_eq!(map.first[5], -1);
    // neighbours are untouched by the poisoned pixel
    let (clean, _) = engine.run(&data.stack).unwrap();
    for px in (0..m).filter(|&px| px != 5) {
        assert_eq!(map.breaks[px], clean.breaks[px], "pixel {px}");
        assert_eq!(map.momax[px].to_bits(), clean.momax[px].to_bits(), "pixel {px}");
    }
}

#[test]
fn window_matrix_exact_band_structure() {
    // N=6, n=4, h=2 → 2 monitor rows; row i has ones at columns
    // n+i-h+1 ..= n+i
    let w = window_matrix_f32(6, 4, 2);
    #[rustfmt::skip]
    let want: Vec<f32> = vec![
        0.0, 0.0, 0.0, 1.0, 1.0, 0.0,
        0.0, 0.0, 0.0, 0.0, 1.0, 1.0,
    ];
    assert_eq!(w, want);
}
