//! Fault-injection matrix for the gateway's failure detectors, driven
//! by [`bfast::gateway::chaos::ChaosProxy`] so every network pathology
//! is provoked *deterministically* — no racing real processes:
//!
//! * a **delayed** worker (high latency, still answering) must be
//!   treated as slow, not dead — no burial, no rebalance;
//! * a **half-open** worker (accepts, never answers) must be detected
//!   by timeout and the run rebalanced within a bounded wall-clock;
//! * an **accepted-submit-then-black-holed-poll** worker — the
//!   nastiest sequence, the shard is live on the other side — must be
//!   buried mid-run and its range rescued bit-identically;
//! * **dropped** connections (accept + close) must fail fast, well
//!   under the configured I/O timeout, not wait it out.

use bfast::api::{AnalysisRequest, ParamSpec, SceneSource};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::gateway::chaos::{ChaosProxy, Mode};
use bfast::gateway::{Gateway, GatewayConfig};
use bfast::json;
use bfast::params::BfastParams;
use bfast::raster::{io as rio, BreakMap, TimeStack};
use bfast::serve::http::roundtrip;
use bfast::serve::{ServeConfig, Server};
use bfast::synth::ArtificialDataset;
use std::time::{Duration, Instant};

/// Analysis shape shared by every test: N=48, n=36, h=12, k=1.
const PQ: &str = "?n-hist=36&h=12&k=1&freq=12&alpha=0.05";

fn params_new(n_total: usize) -> BfastParams {
    BfastParams::new(n_total, 36, 12, 1, 12.0, 0.05).unwrap()
}

fn param_spec() -> ParamSpec {
    ParamSpec {
        n_total: Some(48),
        n_hist: 36,
        h: 12,
        k: 1,
        freq: 12.0,
        alpha: 0.05,
        lambda: None,
    }
}

fn scene(m: usize, seed: u64) -> TimeStack {
    let mut data = ArtificialDataset::new(params_new(48), m, seed).generate();
    if m >= 8 {
        let d = data.stack.data_mut();
        for t in 0..48 {
            d[t * m] = f32::NAN;
        }
        for t in 10..14 {
            d[t * m + 3] = f32::NAN;
        }
    }
    data.stack
}

fn reference_map(stack: &TimeStack) -> BreakMap {
    BfastRunner::emulated(RunnerConfig::default())
        .unwrap()
        .run(stack, &params_new(48))
        .unwrap()
        .map
}

fn assert_maps_identical(a: &BreakMap, b: &BreakMap, ctx: &str) {
    assert_eq!(a.breaks, b.breaks, "{ctx}: breaks differ");
    assert_eq!(a.first, b.first, "{ctx}: first differ");
    assert_eq!(a.momax.len(), b.momax.len(), "{ctx}: momax length");
    for (px, (x, y)) in a.momax.iter().zip(&b.momax).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: momax differs at px {px}: {x} vs {y}");
    }
}

fn get(addr: &str, path: &str) -> (u16, Vec<u8>) {
    roundtrip(addr, "GET", path, "", &[]).unwrap()
}

fn parse_json(body: &[u8]) -> json::Value {
    json::parse(std::str::from_utf8(body).unwrap().trim()).unwrap()
}

fn parse_map(body: &[u8]) -> BreakMap {
    let v = parse_json(body);
    let ints = |key: &str| -> Vec<i32> {
        v.get(key)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect()
    };
    let momax = v
        .get("momax")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect();
    BreakMap { breaks: ints("breaks"), first: ints("first"), momax }
}

fn start_worker() -> Server {
    Server::start(ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() }).unwrap()
}

fn gw_cfg() -> GatewayConfig {
    GatewayConfig {
        addr: "127.0.0.1:0".into(),
        poll: Duration::from_millis(5),
        sweep: Duration::from_millis(50),
        ..Default::default()
    }
}

fn submit_json(gw: &str, req: &AnalysisRequest) -> u64 {
    let (status, body) =
        roundtrip(gw, "POST", "/v1/runs", "application/json", req.to_json_string().as_bytes())
            .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    parse_json(&body).get("job").unwrap().as_usize().unwrap() as u64
}

fn submit_bin(gw: &str, stack: &TimeStack) -> u64 {
    let (status, body) = roundtrip(
        gw,
        "POST",
        &format!("/v1/runs{PQ}"),
        "application/octet-stream",
        &rio::stack_to_bytes(stack),
    )
    .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    parse_json(&body).get("job").unwrap().as_usize().unwrap() as u64
}

fn wait_finished(gw: &str, id: u64, deadline: Duration) -> json::Value {
    let t0 = Instant::now();
    loop {
        let (status, body) = get(gw, &format!("/v1/runs/{id}"));
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let v = parse_json(&body);
        let s = v.get("status").unwrap().as_str().unwrap();
        if s == "done" || s == "failed" || s == "cancelled" {
            return v;
        }
        assert!(
            t0.elapsed() < deadline,
            "job {id} still {s} after {deadline:?} — the gateway hung"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn wait_alive(gw: &str, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, body) = get(gw, "/healthz");
        assert_eq!(status, 200);
        if parse_json(&body).get("workers_alive").unwrap().as_usize().unwrap() == want {
            return;
        }
        assert!(Instant::now() < deadline, "fleet never reached {want} live worker(s)");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn gw_metric(gw: &str, name: &str) -> u64 {
    let (status, body) = get(gw, "/metrics");
    assert_eq!(status, 200);
    String::from_utf8(body)
        .unwrap()
        .lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.trim().parse().ok()))
        .unwrap_or_else(|| panic!("metric {name} missing"))
}

fn observe_mid_run(worker: &str) {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let (status, body) = get(worker, "/v1/runs");
        assert_eq!(status, 200);
        let mid = parse_json(&body).get("jobs").unwrap().as_arr().unwrap().iter().any(|j| {
            j.get("status").unwrap().as_str().unwrap() == "running"
                && j.get("progress").unwrap().as_f64().unwrap() > 0.0
        });
        if mid {
            return;
        }
        assert!(Instant::now() < deadline, "{worker}: no shard reached mid-run");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Slow ≠ dead: with every connection held 150 ms, the health sweep
/// (probe timeout well above the latency) must keep the worker alive
/// through repeated sweeps, and a run placed on it completes with
/// **zero** rebalances.
#[test]
fn delayed_worker_is_slow_not_dead() {
    let w = start_worker();
    let proxy = ChaosProxy::start(&w.addr().to_string()).unwrap();
    proxy.set_mode(Mode::Delay(Duration::from_millis(150)));

    let mut cfg = gw_cfg();
    cfg.workers = vec![proxy.addr().to_string()];
    cfg.io_timeout = Duration::from_secs(2);
    cfg.heartbeat_timeout = Duration::from_millis(800);
    let gw = Gateway::start(cfg).unwrap();
    let gaddr = gw.addr().to_string();
    wait_alive(&gaddr, 1);

    // several sweep periods of sustained latency: never buried
    for _ in 0..5 {
        std::thread::sleep(Duration::from_millis(100));
        let (status, body) = get(&gaddr, "/healthz");
        assert_eq!(status, 200);
        assert_eq!(
            parse_json(&body).get("workers_alive").unwrap().as_usize().unwrap(),
            1,
            "a slow worker was buried as dead"
        );
    }

    let stack = scene(120, 5);
    let reference = reference_map(&stack);
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = param_spec();
    let id = submit_json(&gaddr, &req);
    let done = wait_finished(&gaddr, id, Duration::from_secs(60));
    assert_eq!(
        done.get("status").unwrap().as_str().unwrap(),
        "done",
        "{}",
        done.to_string_compact()
    );
    let (status, body) = get(&gaddr, &format!("/v1/runs/{id}/map"));
    assert_eq!(status, 200);
    assert_maps_identical(&parse_map(&body), &reference, "delayed worker vs direct");
    assert_eq!(
        gw_metric(&gaddr, "bfast_gateway_rebalances_total"),
        0,
        "latency alone must never trigger a rebalance"
    );

    gw.stop().unwrap();
    proxy.stop();
    w.stop().unwrap();
}

/// Half-open: one worker accepts connections but never answers
/// (the harshest failure — detectable only by timeout). The placement
/// must time out, bury it, and rebalance onto the healthy worker
/// within a wall-clock bounded by a few I/O timeouts.
#[test]
fn half_open_worker_is_buried_and_the_run_rebalances() {
    let w1 = start_worker();
    let w2 = start_worker();
    let proxy = ChaosProxy::start(&w2.addr().to_string()).unwrap();
    let mut cfg = gw_cfg();
    cfg.workers = vec![w1.addr().to_string(), proxy.addr().to_string()];
    cfg.io_timeout = Duration::from_millis(400);
    // park the sweep after its first (immediate, healthy) pass so the
    // in-flight placement — not the health prober — finds the corpse
    cfg.sweep = Duration::from_secs(30);
    cfg.heartbeat_timeout = Duration::from_secs(60);
    let gw = Gateway::start(cfg).unwrap();
    let gaddr = gw.addr().to_string();
    wait_alive(&gaddr, 2);

    proxy.set_mode(Mode::Blackhole);
    proxy.kill_connections();

    let stack = scene(600, 13);
    let reference = reference_map(&stack);
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = param_spec();
    let t0 = Instant::now();
    let id = submit_json(&gaddr, &req);
    let done = wait_finished(&gaddr, id, Duration::from_secs(30));
    let wall = t0.elapsed();
    assert_eq!(
        done.get("status").unwrap().as_str().unwrap(),
        "done",
        "{}",
        done.to_string_compact()
    );
    assert!(
        gw_metric(&gaddr, "bfast_gateway_rebalances_total") >= 1,
        "the half-open worker must be detected and rebalanced away"
    );
    assert!(
        wall < Duration::from_secs(15),
        "half-open detection took {wall:?} — not bounded by the I/O timeout"
    );
    let (status, body) = get(&gaddr, &format!("/v1/runs/{id}/map"));
    assert_eq!(status, 200);
    assert_maps_identical(&parse_map(&body), &reference, "half-open rebalance vs direct");

    gw.stop().unwrap();
    proxy.stop();
    w1.stop().unwrap();
    w2.stop().unwrap();
}

/// The nastiest sequence: the submit is **accepted** (the shard runs
/// on the worker), then every poll is black-holed. The gateway must
/// not trust the accepted submit — the dead poll channel buries the
/// worker mid-run and the range is rescued on the survivor,
/// bit-identically.
#[test]
fn blackholed_poll_after_accepted_submit_rebalances() {
    let w1 = start_worker();
    let w2 = start_worker();
    let proxy = ChaosProxy::start(&w2.addr().to_string()).unwrap();
    let mut cfg = gw_cfg();
    cfg.workers = vec![w1.addr().to_string(), proxy.addr().to_string()];
    cfg.io_timeout = Duration::from_millis(500);
    cfg.heartbeat_timeout = Duration::from_secs(2);
    let gw = Gateway::start(cfg).unwrap();
    let gaddr = gw.addr().to_string();
    wait_alive(&gaddr, 2);

    let stack = scene(100_000, 3);
    let reference = reference_map(&stack);
    let id = submit_bin(&gaddr, &stack);
    // the shard is provably accepted and executing before the link
    // goes half-open
    observe_mid_run(&w2.addr().to_string());
    let killed = Instant::now();
    proxy.set_mode(Mode::Blackhole);
    proxy.kill_connections();

    let done = wait_finished(&gaddr, id, Duration::from_secs(300));
    assert_eq!(
        done.get("status").unwrap().as_str().unwrap(),
        "done",
        "{}",
        done.to_string_compact()
    );
    assert!(
        gw_metric(&gaddr, "bfast_gateway_rebalances_total") >= 1,
        "an accepted submit must not mask the dead poll channel"
    );
    assert!(
        killed.elapsed() < Duration::from_secs(120),
        "recovery after the black-holed poll took {:?}",
        killed.elapsed()
    );
    let w1_addr = w1.addr().to_string();
    let (_, body) = get(&gaddr, &format!("/v1/runs/{id}"));
    let rescued = parse_json(&body);
    let all_on_survivor = rescued
        .get("shards")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .all(|s| s.get("worker").unwrap().as_str().unwrap() == w1_addr);
    assert!(all_on_survivor, "{}", rescued.to_string_compact());

    let (status, body) = get(&gaddr, &format!("/v1/runs/{id}/map"));
    assert_eq!(status, 200);
    assert_maps_identical(&parse_map(&body), &reference, "black-holed poll vs direct");

    gw.stop().unwrap();
    proxy.stop();
    w1.stop().unwrap();
    w2.stop().unwrap();
}

/// Dropped connections (accept + immediate close) must be recognised
/// as a *fast* failure: even with a deliberately huge I/O timeout the
/// rebalance completes in seconds, because a closed socket is an
/// error, not a timeout.
#[test]
fn dropped_connections_fail_fast_without_waiting_for_timeouts() {
    let w1 = start_worker();
    let w2 = start_worker();
    let proxy = ChaosProxy::start(&w2.addr().to_string()).unwrap();
    let mut cfg = gw_cfg();
    cfg.workers = vec![w1.addr().to_string(), proxy.addr().to_string()];
    // the contrast with the half-open case: this timeout would make a
    // blackhole take ~16 s to detect, but Drop must not wait on it
    cfg.io_timeout = Duration::from_secs(8);
    cfg.sweep = Duration::from_secs(30);
    cfg.heartbeat_timeout = Duration::from_secs(60);
    let gw = Gateway::start(cfg).unwrap();
    let gaddr = gw.addr().to_string();
    wait_alive(&gaddr, 2);

    proxy.set_mode(Mode::Drop);
    proxy.kill_connections();

    let stack = scene(600, 21);
    let reference = reference_map(&stack);
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = param_spec();
    let t0 = Instant::now();
    let id = submit_json(&gaddr, &req);
    let done = wait_finished(&gaddr, id, Duration::from_secs(30));
    let wall = t0.elapsed();
    assert_eq!(
        done.get("status").unwrap().as_str().unwrap(),
        "done",
        "{}",
        done.to_string_compact()
    );
    assert!(
        gw_metric(&gaddr, "bfast_gateway_rebalances_total") >= 1,
        "the dropped worker must be rebalanced away"
    );
    assert!(
        wall < Duration::from_secs(6),
        "drop took {wall:?} — detection waited on a timeout instead of the error"
    );
    let (status, body) = get(&gaddr, &format!("/v1/runs/{id}/map"));
    assert_eq!(status, 200);
    assert_maps_identical(&parse_map(&body), &reference, "dropped worker vs direct");

    gw.stop().unwrap();
    proxy.stop();
    w1.stop().unwrap();
    w2.stop().unwrap();
}
