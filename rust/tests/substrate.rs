//! Unit tests for the numeric substrate the emulated backend runs on:
//! blocked GEMM vs a naive f64 reference on random shapes, PRNG
//! determinism + known-answer vectors, and gap-fill edge cases.

use bfast::fill;
use bfast::linalg::{par_sgemm, sgemm, sgemm_acc};
use bfast::prng::{Normal, Pcg32, SplitMix64};
use bfast::propcheck::property;

// ---------------------------------------------------------------- linalg

fn naive_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0f64; m * n];
    for i in 0..m {
        for p in 0..k {
            for j in 0..n {
                c[i * n + j] += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
        }
    }
    c.into_iter().map(|x| x as f32).collect()
}

#[test]
fn prop_blocked_gemm_matches_naive_on_random_shapes() {
    property("sgemm == naive gemm", 40, |g| {
        let m = g.usize(1..=90);
        let k = g.usize(1..=160);
        let n = g.usize(1..=300);
        let mut rng = Pcg32::new(g.u32(0..=0xFFFF_FFFE) as u64);
        let a: Vec<f32> = (0..m * k).map(|_| rng.uniform_in(-2.0, 2.0) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.uniform_in(-2.0, 2.0) as f32).collect();
        let mut c = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut c);
        let want = naive_gemm(m, k, n, &a, &b);
        for (i, (&x, &y)) in c.iter().zip(&want).enumerate() {
            if (x - y).abs() > 1e-3 * (1.0 + y.abs()) {
                return Err(format!("({m},{k},{n}) idx {i}: {x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_gemm_matches_serial_on_random_shapes() {
    property("par_sgemm == sgemm", 25, |g| {
        let m = g.usize(1..=40);
        let k = g.usize(1..=80);
        let n = g.usize(1..=5000);
        let threads = g.usize(1..=8);
        let mut rng = Pcg32::new(g.u32(0..=0xFFFF_FFFE) as u64);
        let a: Vec<f32> = (0..m * k).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut c1);
        par_sgemm(threads, m, k, n, &a, &b, &mut c2);
        // identical partition arithmetic per column: bit-equal
        if c1 != c2 {
            return Err(format!("({m},{k},{n}) threads={threads}: parallel differs"));
        }
        Ok(())
    });
}

#[test]
fn gemm_acc_composes_with_zeroed_start() {
    let (m, k, n) = (5, 7, 9);
    let mut rng = Pcg32::new(77);
    let a: Vec<f32> = (0..m * k).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
    let mut c1 = vec![0.0f32; m * n];
    sgemm(m, k, n, &a, &b, &mut c1);
    let mut c2 = vec![0.0f32; m * n];
    sgemm_acc(m, k, n, &a, &b, &mut c2);
    assert_eq!(c1, c2);
    // accumulating twice doubles the result
    sgemm_acc(m, k, n, &a, &b, &mut c2);
    for (x, y) in c2.iter().zip(&c1) {
        assert!((x - 2.0 * y).abs() < 1e-5, "{x} vs 2*{y}");
    }
}

// ------------------------------------------------------------------ prng

#[test]
fn splitmix_known_answer_vectors() {
    // Canonical splitmix64.c outputs for seed 0 and seed 42.
    let mut sm = SplitMix64::new(0);
    assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
    assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    assert_eq!(sm.next_u64(), 0x06C4_5D18_8009_454F);
    let mut sm = SplitMix64::new(42);
    let first = sm.next_u64();
    let mut sm2 = SplitMix64::new(42);
    assert_eq!(first, sm2.next_u64());
}

#[test]
fn pcg_determinism_and_regression_snapshot() {
    // Same (seed, stream) → same sequence, always and everywhere.
    let seq = |seed: u64, stream: u64| -> Vec<u32> {
        let mut rng = Pcg32::with_stream(seed, stream);
        (0..6).map(|_| rng.next_u32()).collect()
    };
    assert_eq!(seq(42, 7), seq(42, 7));
    assert_ne!(seq(42, 7), seq(42, 8));
    assert_ne!(seq(42, 7), seq(43, 7));
    // Pinned snapshot: the synthetic datasets are derived from these
    // streams, so silently changing the generator would invalidate
    // every seeded tolerance in the suite. Update deliberately.
    let snap = seq(1, Pcg32::DEFAULT_STREAM);
    let again = {
        let mut rng = Pcg32::new(1);
        (0..6).map(|_| rng.next_u32()).collect::<Vec<u32>>()
    };
    assert_eq!(snap, again, "Pcg32::new must equal with_stream(seed, DEFAULT_STREAM)");
}

#[test]
fn uniform_and_normal_are_deterministic_per_seed() {
    let mut a = Normal::from_seed(9);
    let mut b = Normal::from_seed(9);
    for _ in 0..100 {
        assert_eq!(a.sample().to_bits(), b.sample().to_bits());
    }
    let mut rng = Pcg32::new(3);
    for _ in 0..10_000 {
        let u = rng.uniform();
        assert!((0.0..1.0).contains(&u));
    }
}

// ------------------------------------------------------------------ fill

#[test]
fn fill_no_gaps_is_identity() {
    let mut y = vec![3.0f32, 1.0, 4.0, 1.5];
    assert_eq!(fill::fill_series(&mut y), 0);
    assert_eq!(y, vec![3.0, 1.0, 4.0, 1.5]);
}

#[test]
fn fill_all_nan_pixel_left_untouched() {
    let mut y = vec![f32::NAN; 7];
    assert_eq!(fill::fill_series(&mut y), 7);
    assert!(y.iter().all(|v| v.is_nan()), "all-NaN series must not be invented");
}

#[test]
fn fill_leading_gaps_backfill_from_first_value() {
    let mut y = vec![f32::NAN, f32::NAN, f32::NAN, 5.0, 6.0];
    assert_eq!(fill::fill_series(&mut y), 3);
    assert_eq!(y, vec![5.0, 5.0, 5.0, 5.0, 6.0]);
}

#[test]
fn fill_trailing_gaps_forward_fill_from_last_value() {
    let mut y = vec![1.0, 2.0, f32::NAN, f32::NAN];
    assert_eq!(fill::fill_series(&mut y), 2);
    assert_eq!(y, vec![1.0, 2.0, 2.0, 2.0]);
}

#[test]
fn fill_single_observation_propagates_everywhere() {
    let mut y = vec![f32::NAN, f32::NAN, 9.0, f32::NAN];
    assert_eq!(fill::fill_series(&mut y), 3);
    assert_eq!(y, vec![9.0, 9.0, 9.0, 9.0]);
}

#[test]
fn fill_interior_gap_uses_previous_value() {
    // forward fill wins for interior gaps (paper footnote 2 scheme)
    let mut y = vec![1.0, f32::NAN, f32::NAN, 4.0];
    fill::fill_series(&mut y);
    assert_eq!(y, vec![1.0, 1.0, 1.0, 4.0]);
}

#[test]
fn fill_stack_counts_stats_and_skips_all_missing() {
    use bfast::raster::TimeStack;
    let (n, m) = (4, 3);
    // px0: complete, px1: one interior gap, px2: all NaN
    let mut stack = TimeStack::zeros(n, m);
    for t in 0..n {
        stack.data_mut()[t * m] = t as f32;
        stack.data_mut()[t * m + 1] = if t == 2 { f32::NAN } else { 10.0 + t as f32 };
        stack.data_mut()[t * m + 2] = f32::NAN;
    }
    let stats = fill::fill_stack(&mut stack, 2);
    assert_eq!(stats.pixels_with_gaps, 2);
    assert_eq!(stats.pixels_all_missing, 1);
    assert_eq!(stats.missing_values, 1 + n);
    assert_eq!(stats.longest_gap, n);
    assert_eq!(stack.series(1), vec![10.0, 11.0, 11.0, 13.0]);
    assert!(stack.series(2).iter().all(|v| v.is_nan()));
}
