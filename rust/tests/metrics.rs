//! Prometheus exposition lint against a LIVE serve and gateway: every
//! sample family is announced by `# HELP` + `# TYPE` before its first
//! sample, no series is emitted twice, histogram buckets are
//! cumulative (monotone, `+Inf` last, `_count` == the `+Inf` bucket),
//! counters follow the `_total` naming convention, and the build-info
//! gauge identifies the binary. A renamed or malformed family breaks
//! dashboards silently — this test makes it break CI loudly instead.

use bfast::api::{AnalysisRequest, ParamSpec, SceneSource};
use bfast::gateway::{Gateway, GatewayConfig};
use bfast::json;
use bfast::params::BfastParams;
use bfast::serve::http::roundtrip;
use bfast::serve::{ServeConfig, Server};
use bfast::synth::ArtificialDataset;
use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

fn small_request() -> AnalysisRequest {
    let params = BfastParams::new(48, 36, 12, 1, 12.0, 0.05).unwrap();
    let stack = ArtificialDataset::new(params, 120, 11).generate().stack;
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = ParamSpec {
        n_total: Some(48),
        n_hist: 36,
        h: 12,
        k: 1,
        freq: 12.0,
        alpha: 0.05,
        lambda: None,
    };
    req
}

fn submit_and_wait(addr: &str) {
    let req = small_request();
    let (status, body) =
        roundtrip(addr, "POST", "/v1/runs", "application/json", req.to_json_string().as_bytes())
            .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let id = json::parse(std::str::from_utf8(&body).unwrap().trim())
        .unwrap()
        .get("job")
        .unwrap()
        .as_usize()
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let (status, body) = roundtrip(addr, "GET", &format!("/v1/runs/{id}"), "", &[]).unwrap();
        assert_eq!(status, 200);
        let v = json::parse(std::str::from_utf8(&body).unwrap().trim()).unwrap();
        match v.get("status").unwrap().as_str().unwrap() {
            "done" => return,
            "failed" | "cancelled" => panic!("{}", v.to_string_compact()),
            _ => {}
        }
        assert!(Instant::now() < deadline, "job never finished");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn scrape(addr: &str) -> String {
    let (status, body) = roundtrip(addr, "GET", "/metrics", "", &[]).unwrap();
    assert_eq!(status, 200);
    String::from_utf8(body).unwrap()
}

/// Family name for a sample line: strip histogram sample suffixes when
/// (and only when) the base family is declared as a histogram.
fn family_of<'a>(name: &'a str, types: &HashMap<&'a str, &'a str>) -> &'a str {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            if types.get(base) == Some(&"histogram") {
                return base;
            }
        }
    }
    name
}

/// The lint proper — panics with the offending line on any violation.
fn lint_exposition(text: &str, ctx: &str) {
    let mut helps: HashSet<&str> = HashSet::new();
    let mut types: HashMap<&str, &str> = HashMap::new();
    // two passes: TYPE declarations first, so histogram sample names
    // can be resolved to their family regardless of line order
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (name, ty) = (it.next().unwrap(), it.next().unwrap());
            assert!(
                !types.contains_key(name),
                "{ctx}: duplicate # TYPE for {name}"
            );
            assert!(
                matches!(ty, "counter" | "gauge" | "histogram"),
                "{ctx}: unknown type {ty:?} for {name}"
            );
            if ty == "counter" {
                assert!(
                    name.ends_with("_total"),
                    "{ctx}: counter {name} must end in _total"
                );
            }
            types.insert(name, ty);
        } else if let Some(rest) = line.strip_prefix("# HELP ") {
            helps.insert(rest.split_whitespace().next().unwrap());
        }
    }

    let mut seen_series: HashSet<&str> = HashSet::new();
    // per-histogram bucket state: (last upper bound, last cumulative
    // count, saw +Inf, +Inf count, _count value)
    struct HistState {
        last_le: f64,
        last_n: f64,
        inf: Option<f64>,
        count: Option<f64>,
    }
    let mut hists: HashMap<&str, HistState> = HashMap::new();

    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = line
            .rsplit_once(' ')
            .unwrap_or_else(|| panic!("{ctx}: malformed sample line {line:?}"));
        let value: f64 = value.parse().unwrap_or_else(|_| {
            panic!("{ctx}: non-numeric value in {line:?}");
        });
        assert!(
            seen_series.insert(series),
            "{ctx}: series {series} emitted twice"
        );
        let name = series.split(['{', ' ']).next().unwrap();
        let family = family_of(name, &types);
        assert!(
            types.contains_key(family),
            "{ctx}: sample {name} has no # TYPE {family}"
        );
        assert!(
            helps.contains(family),
            "{ctx}: sample {name} has no # HELP {family}"
        );

        if types.get(family) == Some(&"histogram") {
            let st = hists.entry(family).or_insert(HistState {
                last_le: f64::NEG_INFINITY,
                last_n: 0.0,
                inf: None,
                count: None,
            });
            if name.ends_with("_bucket") {
                let le = series
                    .split("le=\"")
                    .nth(1)
                    .and_then(|s| s.split('"').next())
                    .unwrap_or_else(|| panic!("{ctx}: bucket without le label: {line:?}"));
                assert!(st.inf.is_none(), "{ctx}: {family} bucket after +Inf: {line:?}");
                if le == "+Inf" {
                    st.inf = Some(value);
                } else {
                    let le: f64 = le.parse().unwrap();
                    assert!(le > st.last_le, "{ctx}: {family} bucket bounds not increasing");
                    st.last_le = le;
                }
                assert!(
                    value >= st.last_n,
                    "{ctx}: {family} bucket counts not cumulative at le={le}"
                );
                st.last_n = value;
            } else if name.ends_with("_count") {
                st.count = Some(value);
            }
        }
    }
    for (family, st) in &hists {
        let inf = st.inf.unwrap_or_else(|| panic!("{ctx}: {family} has no +Inf bucket"));
        let count = st.count.unwrap_or_else(|| panic!("{ctx}: {family} has no _count"));
        assert_eq!(inf, count, "{ctx}: {family} _count must equal the +Inf bucket");
    }
    assert!(!seen_series.is_empty(), "{ctx}: empty exposition");
}

fn check_build_info(text: &str, ctx: &str) {
    let line = text
        .lines()
        .find(|l| l.starts_with("bfast_build_info{"))
        .unwrap_or_else(|| panic!("{ctx}: bfast_build_info sample missing"));
    for label in ["version=\"", "git_rev=\"", "profile=\""] {
        assert!(line.contains(label), "{ctx}: build info lacks {label}...: {line}");
    }
    assert!(line.ends_with(" 1"), "{ctx}: build info gauge must be 1: {line}");
}

#[test]
fn serve_exposition_is_well_formed() {
    let w = Server::start(ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() })
        .unwrap();
    let addr = w.addr().to_string();
    // one completed run populates the queue-wait and run-latency
    // histograms and the counter families
    submit_and_wait(&addr);
    let text = scrape(&addr);
    lint_exposition(&text, "serve");
    check_build_info(&text, "serve");
    for family in ["bfast_queue_wait_seconds", "bfast_run_latency_seconds"] {
        assert!(
            text.contains(&format!("# TYPE {family} histogram")),
            "serve: {family} histogram missing"
        );
        let count = text
            .lines()
            .find(|l| l.starts_with(&format!("{family}_count")))
            .and_then(|l| l.rsplit_once(' '))
            .and_then(|(_, v)| v.parse::<f64>().ok())
            .unwrap();
        assert!(count >= 1.0, "serve: {family} observed nothing");
    }
    check_cache_families(&text, "serve");
    w.stop().unwrap();
}

/// The result-cache families the ISSUE pins on BOTH expositions:
/// three counters plus the held-bytes gauge, each HELP/TYPE-announced
/// (the lint already proved that — this pins their names).
fn check_cache_families(text: &str, ctx: &str) {
    for family in
        ["bfast_cache_hits_total", "bfast_cache_misses_total", "bfast_cache_evictions_total"]
    {
        assert!(
            text.contains(&format!("# TYPE {family} counter")),
            "{ctx}: {family} counter missing"
        );
    }
    assert!(
        text.contains("# TYPE bfast_cache_bytes gauge"),
        "{ctx}: bfast_cache_bytes gauge missing"
    );
}

#[test]
fn gateway_exposition_is_well_formed() {
    let w = Server::start(ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() })
        .unwrap();
    let cfg = GatewayConfig {
        addr: "127.0.0.1:0".into(),
        workers: vec![w.addr().to_string()],
        poll: Duration::from_millis(5),
        sweep: Duration::from_millis(50),
        ..Default::default()
    };
    let gw = Gateway::start(cfg).unwrap();
    let gaddr = gw.addr().to_string();
    submit_and_wait(&gaddr);
    let text = scrape(&gaddr);
    lint_exposition(&text, "gateway");
    check_build_info(&text, "gateway");
    assert!(
        text.contains("# TYPE bfast_gateway_run_latency_seconds histogram"),
        "gateway: run latency histogram missing"
    );
    assert!(
        text.contains("# TYPE bfast_gateway_rebalances_total counter"),
        "gateway: rebalance counter missing"
    );
    check_cache_families(&text, "gateway");
    gw.stop().unwrap();
    w.stop().unwrap();
}
