//! Property-based integration tests on coordinator/stack invariants
//! (using the in-tree `propcheck` substrate — see DESIGN.md §3).

use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::params::BfastParams;
use bfast::propcheck::property;
use bfast::raster::{BreakMap, ChunkPlan, TimeStack};
use bfast::runtime::EmulatedDevice;
use bfast::synth::ArtificialDataset;

#[test]
fn prop_chunked_assembly_reconstructs_any_map() {
    // Writing per-chunk slices through BreakMap::write_at in ANY chunk
    // order must reproduce the full map (the coordinator's out-of-order
    // completion invariant).
    property("chunked assembly", 120, |g| {
        let m = g.usize(1..=5000);
        let mc = g.usize(1..=700);
        let plan = ChunkPlan::new(m, mc);
        // reference data
        let breaks: Vec<i32> = (0..m).map(|i| (i % 3 == 0) as i32).collect();
        let first: Vec<i32> =
            (0..m).map(|i| if i % 3 == 0 { (i % 40) as i32 } else { -1 }).collect();
        let momax: Vec<f32> = (0..m).map(|i| i as f32 * 0.5).collect();
        let mut order: Vec<usize> = (0..plan.len()).collect();
        // deterministic shuffle from the generator
        for i in (1..order.len()).rev() {
            let j = g.usize(0..=i);
            order.swap(i, j);
        }
        let mut map = BreakMap::zeros(m);
        for idx in order {
            let c = plan.get(idx);
            map.write_at(
                c.start,
                &breaks[c.start..c.end],
                &first[c.start..c.end],
                &momax[c.start..c.end],
            );
        }
        if map.breaks != breaks || map.first != first || map.momax != momax {
            return Err(format!("m={m} mc={mc}: assembled map differs"));
        }
        Ok(())
    });
}

#[test]
fn prop_chunk_copy_roundtrip_with_padding() {
    // copy_chunk_padded must be the exact strided gather of a pixel
    // range, with the pad columns holding the pad value.
    property("chunk copy roundtrip", 80, |g| {
        let n = g.usize(1..=40);
        let m = g.usize(1..=300);
        let mut stack = TimeStack::zeros(n, m);
        for (i, v) in stack.data_mut().iter_mut().enumerate() {
            *v = (i % 251) as f32;
        }
        let start = g.usize(0..=m - 1);
        let end = g.usize(start + 1..=m);
        let padded = (end - start) + g.usize(0..=16);
        let mut buf = vec![-1.0f32; n * padded];
        stack.copy_chunk_padded(start, end, padded, 9.5, &mut buf);
        for t in 0..n {
            for j in 0..padded {
                let got = buf[t * padded + j];
                let want = if j < end - start {
                    stack.data()[t * m + start + j]
                } else {
                    9.5
                };
                if got != want {
                    return Err(format!(
                        "n={n} m={m} [{start},{end}) pad={padded} at ({t},{j}): {got} vs {want}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_slice_pixels_preserves_series() {
    property("slice preserves series", 60, |g| {
        let n = g.usize(2..=30);
        let m = g.usize(2..=200);
        let mut stack = TimeStack::zeros(n, m);
        for (i, v) in stack.data_mut().iter_mut().enumerate() {
            *v = ((i * 7) % 113) as f32;
        }
        let a = g.usize(0..=m - 1);
        let b = g.usize(a + 1..=m);
        let sub = stack.slice_pixels(a, b);
        for px in 0..(b - a) {
            if sub.series(px) != stack.series(a + px) {
                return Err(format!("series {px} differs for [{a},{b})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cpu_engine_invariant_to_thread_count() {
    // The fused CPU engine must be bit-stable across thread counts
    // (each pixel's arithmetic is identical, only the partition moves).
    let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
    property("cpu thread invariance", 12, |g| {
        let m = g.usize(1..=600);
        let seed = g.u32(0..=9999) as u64;
        let data = ArtificialDataset::new(params.clone(), m, seed).generate();
        let e1 = bfast::cpu::FusedCpuBfast::new(params.clone(), &data.stack.time_axis)
            .map_err(|e| e.to_string())?
            .with_threads(1);
        let e4 = bfast::cpu::FusedCpuBfast::new(params.clone(), &data.stack.time_axis)
            .map_err(|e| e.to_string())?
            .with_threads(4);
        let (m1, _) = e1.run(&data.stack).map_err(|e| e.to_string())?;
        let (m4, _) = e4.run(&data.stack).map_err(|e| e.to_string())?;
        if m1.breaks != m4.breaks || m1.momax != m4.momax {
            return Err(format!("m={m} seed={seed}: thread count changed results"));
        }
        Ok(())
    });
}

#[test]
fn prop_emulated_pipeline_equals_cpu_engine() {
    // The full coordinated pipeline (staging, chunking, padding,
    // out-of-order assembly) over the emulated backend must reproduce
    // the scene-wide fused CPU engine bit-for-bit, for any scene size
    // and chunk width.
    let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
    property("emulated pipeline == cpu engine", 10, |g| {
        let m = g.usize(1..=900);
        let mc = g.usize(1..=300);
        let seed = g.u32(0..=9999) as u64;
        let data = ArtificialDataset::new(params.clone(), m, seed).generate();
        let backend = Box::new(EmulatedDevice::new().with_m_chunk(mc));
        let runner = BfastRunner::new(backend, RunnerConfig::default())
            .map_err(|e| e.to_string())?;
        let res = runner.run(&data.stack, &params).map_err(|e| e.to_string())?;
        if res.chunks != m.div_ceil(mc) {
            return Err(format!("m={m} mc={mc}: {} chunks", res.chunks));
        }
        let engine = bfast::cpu::FusedCpuBfast::new(params.clone(), &data.stack.time_axis)
            .map_err(|e| e.to_string())?;
        let (cpu_map, _) = engine.run(&data.stack).map_err(|e| e.to_string())?;
        if res.map.breaks != cpu_map.breaks
            || res.map.first != cpu_map.first
            || res.map.momax != cpu_map.momax
        {
            return Err(format!("m={m} mc={mc} seed={seed}: pipeline diverged from engine"));
        }
        Ok(())
    });
}

#[test]
fn break_map_deterministic_across_scheduling_grid() {
    // The break map must be a pure function of (scene, params): a full
    // grid of queue_depth × staging_threads × backend m_chunk settings
    // on the same synthetic scene yields bitwise-identical results —
    // chunking, padding, backpressure and out-of-order completion are
    // scheduling details, never arithmetic ones.
    let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
    let data = ArtificialDataset::new(params.clone(), 700, 11).generate();
    let run = |queue_depth: usize, staging_threads: usize, m_chunk: usize| {
        let backend = Box::new(EmulatedDevice::new().with_m_chunk(m_chunk));
        let cfg = RunnerConfig { queue_depth, staging_threads, ..Default::default() };
        let runner = BfastRunner::new(backend, cfg).unwrap();
        runner.run(&data.stack, &params).unwrap().map
    };
    let reference = run(2, 2, 1024);
    assert!(reference.break_count() > 0, "scene must exercise both outcomes");
    assert!(reference.break_count() < reference.len());
    for &queue_depth in &[1usize, 2, 4] {
        for &staging_threads in &[1usize, 2, 5] {
            for &m_chunk in &[1usize, 37, 256, 1024] {
                let map = run(queue_depth, staging_threads, m_chunk);
                let ctx = format!("qd={queue_depth} st={staging_threads} mc={m_chunk}");
                assert_eq!(map.breaks, reference.breaks, "{ctx}: breaks");
                assert_eq!(map.first, reference.first, "{ctx}: first");
                assert_eq!(map.momax, reference.momax, "{ctx}: momax");
            }
        }
    }
}

#[test]
fn prop_fill_idempotent_and_gap_free() {
    property("fill idempotent", 60, |g| {
        let n = g.usize(2..=50);
        let mut y: Vec<f32> = (0..n).map(|i| i as f32).collect();
        // punch random holes, maybe all
        let holes = g.usize(0..=n);
        for _ in 0..holes {
            let i = g.usize(0..=n - 1);
            y[i] = f32::NAN;
        }
        let all_nan = y.iter().all(|v| v.is_nan());
        let mut once = y.clone();
        bfast::fill::fill_series(&mut once);
        let mut twice = once.clone();
        bfast::fill::fill_series(&mut twice);
        if all_nan {
            // untouched by contract
            if !once.iter().all(|v| v.is_nan()) {
                return Err("all-NaN series was modified".into());
            }
            return Ok(());
        }
        if once.iter().any(|v| v.is_nan()) {
            return Err(format!("gaps remain: {once:?}"));
        }
        let same = once
            .iter()
            .zip(&twice)
            .all(|(a, b)| (a == b) || (a.is_nan() && b.is_nan()));
        if !same {
            return Err("fill not idempotent".into());
        }
        Ok(())
    });
}
