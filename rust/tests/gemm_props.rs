//! Property suite pinning the tiled GEMM micro-kernel (`linalg::gemm`)
//! to the naive triple-loop oracle — **bitwise**, not approximately.
//!
//! The tiled kernel (MR=4 row micro-tile, KC k-blocking, NC column
//! panels) is only allowed to reorder *which* C elements are touched
//! when; per element the k-updates must apply in increasing-p order
//! with the seed's zero-skip (`av == 0.0` skips the whole row update,
//! so `-0.0` is skipped and NaN `av` is not), each as a plain
//! f32 mul-then-add. That invariant makes every result bit-identical
//! to this oracle, which is what the engine-equivalence tests and the
//! monitor/shard bit-exactness contracts rest on.

use bfast::linalg::gemm::{par_sgemm, sgemm, sgemm_acc};
use bfast::propcheck::{property, Gen};

/// The semantic contract spelled as the obvious triple loop.
fn oracle(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Matrix fill biased toward the values the zero-skip cares about:
/// ~25% exact 0.0 plus -0.0 / NaN / ±inf spikes among ordinary finite
/// entries.
fn special_matrix(g: &mut Gen, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| match g.u32(0..=19) {
            0..=4 => 0.0,
            5 => -0.0,
            6 => f32::NAN,
            7 => f32::INFINITY,
            8 => f32::NEG_INFINITY,
            _ => g.f64(-2.0, 2.0) as f32,
        })
        .collect()
}

/// Deterministic variant for the fixed tile-boundary shapes.
fn det_matrix(len: usize, salt: u64) -> Vec<f32> {
    let mut s = salt.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
    (0..len)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let r = (s >> 33) as u32;
            match r % 16 {
                0 | 1 | 2 => 0.0,
                3 => -0.0,
                4 => f32::NAN,
                5 => f32::INFINITY,
                _ => ((r % 1000) as f32 - 500.0) / 250.0,
            }
        })
        .collect()
}

/// Bit-level view (NaN-safe equality).
fn bits(c: &[f32]) -> Vec<u32> {
    c.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn sgemm_matches_oracle_bitwise_over_random_shapes() {
    property("sgemm = oracle (bitwise)", 60, |g| {
        let (m, k, n) = (g.usize(1..=40), g.usize(1..=260), g.usize(1..=70));
        let a = special_matrix(g, m * k);
        let b = special_matrix(g, k * n);
        let mut got = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut got);
        let mut want = vec![0.0f32; m * n];
        oracle(m, k, n, &a, &b, &mut want);
        if bits(&got) != bits(&want) {
            return Err(format!("m={m} k={k} n={n}: tiled kernel diverges from oracle"));
        }
        Ok(())
    });
}

#[test]
fn sgemm_acc_accumulates_onto_prefill_bitwise() {
    property("sgemm_acc = oracle over prefilled C", 40, |g| {
        let (m, k, n) = (g.usize(1..=24), g.usize(1..=140), g.usize(1..=48));
        let a = special_matrix(g, m * k);
        let b = special_matrix(g, k * n);
        let prefill: Vec<f32> = (0..m * n).map(|_| g.f64(-1.0, 1.0) as f32).collect();
        let mut got = prefill.clone();
        sgemm_acc(m, k, n, &a, &b, &mut got);
        let mut want = prefill;
        oracle(m, k, n, &a, &b, &mut want);
        if bits(&got) != bits(&want) {
            return Err(format!("m={m} k={k} n={n}: acc variant diverges from oracle"));
        }
        Ok(())
    });
}

#[test]
fn par_sgemm_is_bitwise_deterministic_across_thread_counts() {
    property("par_sgemm bitwise == serial for any thread count", 25, |g| {
        let (m, k, n) = (g.usize(1..=60), g.usize(1..=100), g.usize(1..=40));
        let a = special_matrix(g, m * k);
        let b = special_matrix(g, k * n);
        let mut serial = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut serial);
        let want = bits(&serial);
        for threads in [1usize, 2, 3, 5, 8] {
            let mut par = vec![0.0f32; m * n];
            par_sgemm(threads, m, k, n, &a, &b, &mut par);
            if bits(&par) != want {
                return Err(format!("m={m} k={k} n={n} threads={threads}: parallel differs"));
            }
        }
        Ok(())
    });
}

/// Every edge the tiling can get wrong: shapes straddling the MR=4 row
/// micro-tile, the KC=128 k-block, and small odd primes of each.
#[test]
fn tile_boundary_shapes_match_oracle() {
    let mut salt = 0u64;
    for &k in &[1usize, 13, 127, 128, 129] {
        for &m in &[1usize, 3, 4, 5, 7, 13] {
            for &n in &[1usize, 31] {
                salt += 1;
                let a = det_matrix(m * k, salt);
                let b = det_matrix(k * n, salt ^ 0xabcd);
                let mut got = vec![0.0f32; m * n];
                sgemm(m, k, n, &a, &b, &mut got);
                let mut want = vec![0.0f32; m * n];
                oracle(m, k, n, &a, &b, &mut want);
                assert_eq!(bits(&got), bits(&want), "m={m} k={k} n={n}");
            }
        }
    }
}

/// Shapes straddling the NC=4096 serial column panel.
#[test]
fn column_panel_boundaries_match_oracle() {
    for &n in &[4095usize, 4096, 4097] {
        let (m, k) = (5usize, 7usize);
        let a = det_matrix(m * k, n as u64);
        let b = det_matrix(k * n, (n as u64) << 1);
        let mut got = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut got);
        let mut want = vec![0.0f32; m * n];
        oracle(m, k, n, &a, &b, &mut want);
        assert_eq!(bits(&got), bits(&want), "n={n}");
    }
}

/// Shapes straddling the 2048-column parallel panel of `par_sgemm`.
#[test]
fn parallel_panel_boundaries_match_serial() {
    for &n in &[2047usize, 2048, 2049] {
        let (m, k) = (6usize, 5usize);
        let a = det_matrix(m * k, n as u64 ^ 0x55);
        let b = det_matrix(k * n, n as u64 ^ 0xaa);
        let mut serial = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut serial);
        for threads in [2usize, 4] {
            let mut par = vec![0.0f32; m * n];
            par_sgemm(threads, m, k, n, &a, &b, &mut par);
            assert_eq!(bits(&par), bits(&serial), "n={n} threads={threads}");
        }
    }
}
