//! `bfast serve` integration suite — everything over real loopback
//! sockets: break maps served by the API must be **bit-identical** to
//! direct `BfastRunner::run`s of the same scenes, 64 concurrent
//! clients must each get that bit-identical answer, one session must
//! serialise concurrent readers against live ingests, and a
//! killed-and-restarted server must resume its monitor sessions
//! bit-exactly from the state directory.

use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::json;
use bfast::params::BfastParams;
use bfast::raster::{io as rio, BreakMap, TimeStack};
use bfast::runtime::bten::{bten_to_bytes, Tensor};
use bfast::serve::http::{base64_encode, read_response, roundtrip};
use bfast::serve::{ServeConfig, Server};
use bfast::synth::ArtificialDataset;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Analysis shape shared by every test: N=48, n=36, h=12, k=1.
const PQ: &str = "?n-hist=36&h=12&k=1&freq=12&alpha=0.05";

fn params_new(n_total: usize) -> BfastParams {
    BfastParams::new(n_total, 36, 12, 1, 12.0, 0.05).unwrap()
}

fn scene(m: usize, seed: u64) -> TimeStack {
    let mut data = ArtificialDataset::new(params_new(48), m, seed).generate();
    if m >= 8 {
        let d = data.stack.data_mut();
        for t in 0..48 {
            d[t * m] = f32::NAN; // dead pixel
        }
        for t in 10..14 {
            d[t * m + 3] = f32::NAN; // cloud hole
        }
    }
    data.stack
}

fn start_server(state_dir: Option<std::path::PathBuf>, queue: usize, workers: usize) -> Server {
    Server::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        state_dir,
        http_threads: 8,
        job_workers: workers,
        queue_capacity: queue,
        ..Default::default()
    })
    .unwrap()
}

fn get(addr: &str, path: &str) -> (u16, Vec<u8>) {
    roundtrip(addr, "GET", path, "", &[]).unwrap()
}

fn post(addr: &str, path: &str, content_type: &str, body: &[u8]) -> (u16, Vec<u8>) {
    roundtrip(addr, "POST", path, content_type, body).unwrap()
}

fn parse_json(body: &[u8]) -> json::Value {
    json::parse(std::str::from_utf8(body).unwrap().trim()).unwrap()
}

fn parse_map(body: &[u8]) -> BreakMap {
    let v = parse_json(body);
    let ints = |key: &str| -> Vec<i32> {
        v.get(key)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect()
    };
    let momax = v
        .get("momax")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect();
    BreakMap { breaks: ints("breaks"), first: ints("first"), momax }
}

fn assert_maps_identical(a: &BreakMap, b: &BreakMap, ctx: &str) {
    assert_eq!(a.breaks, b.breaks, "{ctx}: breaks differ");
    assert_eq!(a.first, b.first, "{ctx}: first differ");
    assert_eq!(a.momax.len(), b.momax.len(), "{ctx}: momax length");
    for (px, (x, y)) in a.momax.iter().zip(&b.momax).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: momax differs at px {px}: {x} vs {y}");
    }
}

fn wait_job(addr: &str, id: u64) -> json::Value {
    for _ in 0..1500 {
        let (status, body) = get(addr, &format!("/v1/runs/{id}"));
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let v = parse_json(&body);
        match v.get("status").unwrap().as_str().unwrap() {
            "done" => return v,
            "failed" => panic!("job {id} failed: {}", String::from_utf8_lossy(&body)),
            _ => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    panic!("job {id} did not finish in time");
}

/// ROADMAP item: HTTP/1.1 keep-alive — N sequential requests over ONE
/// socket, each answered in full; `Connection: close` ends the
/// exchange with a server-side close.
#[test]
fn keep_alive_serves_many_requests_on_one_socket() {
    use std::io::{Read, Write};
    let server = start_server(None, 4, 1);
    let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for i in 0..5 {
        stream
            .write_all(b"GET /healthz HTTP/1.1\r\nHost: bfast\r\nContent-Length: 0\r\n\r\n")
            .unwrap();
        let (status, body) = read_response(&mut stream).unwrap();
        assert_eq!(status, 200, "request {i} on the shared socket");
        let v = parse_json(&body);
        assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok", "request {i}");
    }
    // Connection: close ends the exchange: one reply, then EOF
    stream
        .write_all(
            b"GET /healthz HTTP/1.1\r\nHost: bfast\r\nConnection: close\r\n\
              Content-Length: 0\r\n\r\n",
        )
        .unwrap();
    let (status, _) = read_response(&mut stream).unwrap();
    assert_eq!(status, 200);
    let mut rest = Vec::new();
    let n = stream.read_to_end(&mut rest).unwrap();
    assert_eq!(n, 0, "server must close after Connection: close");

    // every request on the shared socket was counted individually
    let (status, body) = get(&server.addr().to_string(), "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    let total: u64 = text
        .lines()
        .find_map(|l| l.strip_prefix("bfast_http_requests_total "))
        .unwrap()
        .trim()
        .parse()
        .unwrap();
    assert!(total >= 7, "expected ≥7 counted requests, metrics say {total}");
    server.stop().unwrap();
}

/// Satellite: a full queue answers 429 with a `Retry-After` header
/// and the uniform `{"error": {...}}` envelope (retry hint included),
/// and a polite `roundtrip_retry` submission eventually lands once
/// the queue drains instead of failing outright.
#[test]
fn full_queue_answers_429_with_retry_after_and_backoff_succeeds() {
    use bfast::serve::http::{self, Client};
    let server = start_server(None, 1, 1); // queue capacity 1
    let addr = server.addr().to_string();
    let body = rio::stack_to_bytes(&scene(10_000, 3));

    // fill: keep submitting on one keep-alive socket until the bounded
    // queue refuses (the worker pops the first job; the next occupies
    // the single queue slot)
    let mut client = Client::connect(&addr).unwrap();
    let mut refused = None;
    for _ in 0..10 {
        let (status, headers, resp) = client
            .request_parts(
                "POST",
                &format!("/v1/runs{PQ}"),
                "application/octet-stream",
                &body,
            )
            .unwrap();
        match status {
            202 => continue,
            429 => {
                refused = Some((headers, resp));
                break;
            }
            other => panic!("unexpected HTTP {other}"),
        }
    }
    let (headers, resp) = refused.expect("queue never filled up");
    assert_eq!(http::retry_after(&headers), Some(Duration::from_secs(1)));
    let v = parse_json(&resp);
    let env = v.get("error").unwrap();
    assert_eq!(env.get("status").unwrap().as_usize().unwrap(), 429);
    assert!(
        env.get("message").unwrap().as_str().unwrap().contains("full"),
        "{resp:?}"
    );
    assert_eq!(env.get("retry_after_s").unwrap().as_usize().unwrap(), 1);

    // error envelopes are uniform across paths: a 404 carries one too
    let (status, resp) = get(&addr, "/v1/runs/12345");
    assert_eq!(status, 404);
    let env = parse_json(&resp);
    let env = env.get("error").unwrap();
    assert_eq!(env.get("status").unwrap().as_usize().unwrap(), 404);
    assert_eq!(http::error_message(&resp), "no job 12345");

    // the polite client backs off and eventually gets its 202
    let (status, resp) = http::roundtrip_retry(
        &addr,
        "POST",
        &format!("/v1/runs{PQ}"),
        "application/octet-stream",
        &body,
        8,
    )
    .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&resp));
    server.stop().unwrap();
}

#[test]
fn healthz_metrics_and_unknown_routes() {
    let server = start_server(None, 4, 1);
    let addr = server.addr().to_string();
    let (status, body) = get(&addr, "/healthz");
    assert_eq!(status, 200);
    let v = parse_json(&body);
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");
    assert!(v.get("backend").unwrap().as_str().unwrap().contains("emulated"));

    let (status, body) = get(&addr, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text.contains("bfast_uptime_seconds"), "{text}");
    assert!(text.contains("bfast_queue_capacity 4"), "{text}");

    let (status, _) = get(&addr, "/nope");
    assert_eq!(status, 404);
    let (status, _) = post(&addr, "/healthz", "", &[]);
    assert_eq!(status, 404); // wrong method
    let (status, _) = post(&addr, "/v1/runs", "application/octet-stream", b"not a stack");
    assert_eq!(status, 400);
    // invalid analysis parameters are refused at the door (400), not
    // accepted as a job that only fails later
    let (status, body) = post(
        &addr,
        "/v1/runs?h=0",
        "application/octet-stream",
        &rio::stack_to_bytes(&scene(8, 3)),
    );
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    server.stop().unwrap();
}

#[test]
fn submitted_run_matches_direct_run_bitwise() {
    let stack = scene(200, 7);
    let reference = BfastRunner::emulated(RunnerConfig::default())
        .unwrap()
        .run(&stack, &params_new(48))
        .unwrap()
        .map;

    let server = start_server(None, 4, 1);
    let addr = server.addr().to_string();
    let (status, body) = post(
        &addr,
        &format!("/v1/runs{PQ}"),
        "application/octet-stream",
        &rio::stack_to_bytes(&stack),
    );
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let id = parse_json(&body).get("job").unwrap().as_usize().unwrap() as u64;
    let done = wait_job(&addr, id);
    assert_eq!(done.get("pixels").unwrap().as_usize().unwrap(), 200);

    let (status, body) = get(&addr, &format!("/v1/runs/{id}/map"));
    assert_eq!(status, 200);
    assert_maps_identical(&parse_map(&body), &reference, "served map vs direct run");

    // the momax heatmap renders as a valid PGM too
    let (status, body) = get(&addr, &format!("/v1/runs/{id}/map?format=pgm"));
    assert_eq!(status, 200);
    assert!(body.starts_with(b"P5\n"), "not a PGM");
    server.stop().unwrap();
}

/// Acceptance: ≥ 64 concurrent connections, every returned break map
/// bit-identical to a fresh single-threaded run of the same scene.
#[test]
fn sixty_four_concurrent_clients_get_bit_identical_maps() {
    let stack = scene(64, 21);
    let reference = Arc::new(
        BfastRunner::emulated(RunnerConfig::default())
            .unwrap()
            .run(&stack, &params_new(48))
            .unwrap()
            .map,
    );
    let bytes = Arc::new(rio::stack_to_bytes(&stack));

    let server = start_server(None, 64, 2);
    let addr = server.addr().to_string();
    let handles: Vec<_> = (0..64)
        .map(|i| {
            let addr = addr.clone();
            let bytes = Arc::clone(&bytes);
            let reference = Arc::clone(&reference);
            std::thread::spawn(move || {
                // submit (retrying politely on 429 backpressure)
                let id = loop {
                    let (status, body) = post(
                        &addr,
                        &format!("/v1/runs{PQ}"),
                        "application/octet-stream",
                        &bytes,
                    );
                    match status {
                        202 => {
                            break parse_json(&body).get("job").unwrap().as_usize().unwrap()
                                as u64
                        }
                        429 => std::thread::sleep(Duration::from_millis(10)),
                        other => {
                            panic!("client {i}: HTTP {other}: {}", String::from_utf8_lossy(&body))
                        }
                    }
                };
                wait_job(&addr, id);
                let (status, body) = get(&addr, &format!("/v1/runs/{id}/map"));
                assert_eq!(status, 200, "client {i}");
                assert_maps_identical(&parse_map(&body), &reference, &format!("client {i}"));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.stop().unwrap();
}

#[test]
fn monitor_session_over_loopback_matches_direct_run() {
    let stack = scene(90, 5);
    let server = start_server(None, 4, 1);
    let addr = server.addr().to_string();

    // prime on the first 37 layers of the archive
    let (status, body) = post(
        &addr,
        &format!("/v1/sessions/tile-a{PQ}&init-layers=37"),
        "application/octet-stream",
        &rio::stack_to_bytes(&stack),
    );
    assert_eq!(status, 201, "{}", String::from_utf8_lossy(&body));
    let summary = parse_json(&body);
    assert_eq!(summary.get("layers_seen").unwrap().as_usize().unwrap(), 37);
    // the session derives λ at init (horizon 37/36); a fresh run must
    // use the same λ to be comparable across the grown archive
    let lambda = summary.get("lambda").unwrap().as_f64().unwrap();

    // duplicate name → 409; bad name → 400
    let (status, _) = post(
        &addr,
        &format!("/v1/sessions/tile-a{PQ}&init-layers=37"),
        "application/octet-stream",
        &rio::stack_to_bytes(&stack),
    );
    assert_eq!(status, 409);
    let (status, _) = post(&addr, "/v1/sessions/..evil", "application/octet-stream", &[]);
    assert_eq!(status, 400);

    // ingest the remaining layers, alternating wire formats
    for i in 37..48 {
        let t = stack.time_axis[i];
        let layer = stack.layer(i);
        let (status, body) = if i % 2 == 0 {
            let tensor = Tensor::F32 { shape: vec![layer.len()], data: layer.to_vec() };
            post(
                &addr,
                &format!("/v1/sessions/tile-a/ingest?t={t}"),
                "application/octet-stream",
                &bten_to_bytes(&tensor).unwrap(),
            )
        } else {
            let bytes: Vec<u8> = layer.iter().flat_map(|v| v.to_le_bytes()).collect();
            let doc = format!(
                "{{\"t\": {t}, \"layer_b64\": \"{}\"}}",
                base64_encode(&bytes)
            );
            post(
                &addr,
                "/v1/sessions/tile-a/ingest",
                "application/json",
                doc.as_bytes(),
            )
        };
        assert_eq!(status, 200, "layer {i}: {}", String::from_utf8_lossy(&body));
        let delta = parse_json(&body);
        assert_eq!(delta.get("layer").unwrap().as_usize().unwrap(), i);
    }

    // re-feeding an already-seen time must fail cleanly
    let tensor = Tensor::F32 { shape: vec![90], data: stack.layer(47).to_vec() };
    let (status, _) = post(
        &addr,
        &format!("/v1/sessions/tile-a/ingest?t={}", stack.time_axis[47]),
        "application/octet-stream",
        &bten_to_bytes(&tensor).unwrap(),
    );
    assert_eq!(status, 400);

    // the grown session's map equals a fresh run over the full archive
    let reference = BfastRunner::emulated(RunnerConfig::default())
        .unwrap()
        .run(
            &stack,
            &BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, lambda).unwrap(),
        )
        .unwrap()
        .map;
    let (status, body) = get(&addr, "/v1/sessions/tile-a/map");
    assert_eq!(status, 200);
    assert_maps_identical(&parse_map(&body), &reference, "session map vs fresh run");
    server.stop().unwrap();
}

/// ≥ 8 threads hammering one session while it ingests: every response
/// parses, and the registry's per-session lock keeps reads consistent.
#[test]
fn concurrent_clients_hammer_one_session() {
    let stack = scene(48, 13);
    let server = start_server(None, 4, 1);
    let addr = server.addr().to_string();
    let (status, body) = post(
        &addr,
        &format!("/v1/sessions/busy{PQ}&init-layers=37"),
        "application/octet-stream",
        &rio::stack_to_bytes(&stack),
    );
    assert_eq!(status, 201, "{}", String::from_utf8_lossy(&body));
    let lambda = parse_json(&body).get("lambda").unwrap().as_f64().unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..8)
        .map(|i| {
            let addr = addr.clone();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut reads = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let (status, body) = get(&addr, "/v1/sessions/busy");
                    assert_eq!(status, 200, "reader {i}");
                    let v = parse_json(&body);
                    let seen = v.get("layers_seen").unwrap().as_usize().unwrap();
                    assert!((37..=48).contains(&seen), "reader {i}: layers_seen {seen}");
                    let (status, body) = get(&addr, "/v1/sessions/busy/map");
                    assert_eq!(status, 200, "reader {i}");
                    let map = parse_map(&body);
                    assert_eq!(map.breaks.len(), 48, "reader {i}");
                    reads += 1;
                }
                assert!(reads > 0, "reader {i} never completed a read");
            })
        })
        .collect();

    for i in 37..48 {
        let tensor = Tensor::F32 { shape: vec![48], data: stack.layer(i).to_vec() };
        let (status, _) = post(
            &addr,
            &format!("/v1/sessions/busy/ingest?t={}", stack.time_axis[i]),
            "application/octet-stream",
            &bten_to_bytes(&tensor).unwrap(),
        );
        assert_eq!(status, 200, "layer {i}");
        std::thread::sleep(Duration::from_millis(5));
    }
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        r.join().unwrap();
    }

    let reference = BfastRunner::emulated(RunnerConfig::default())
        .unwrap()
        .run(
            &stack,
            &BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, lambda).unwrap(),
        )
        .unwrap()
        .map;
    let (_, body) = get(&addr, "/v1/sessions/busy/map");
    assert_maps_identical(&parse_map(&body), &reference, "hammered session final map");
    server.stop().unwrap();
}

/// Acceptance: a killed-and-restarted server resumes its monitor
/// sessions bit-exactly from the state directory.
#[test]
fn restarted_server_resumes_sessions_bit_exactly() {
    let dir = std::env::temp_dir().join(format!("bfast_serve_state_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let stack = scene(70, 29);

    // first server: prime + ingest the first half of the monitor period
    let server = start_server(Some(dir.clone()), 4, 1);
    let addr = server.addr().to_string();
    let (status, body) = post(
        &addr,
        &format!("/v1/sessions/tile-r{PQ}&init-layers=37"),
        "application/octet-stream",
        &rio::stack_to_bytes(&stack),
    );
    assert_eq!(status, 201, "{}", String::from_utf8_lossy(&body));
    let lambda = parse_json(&body).get("lambda").unwrap().as_f64().unwrap();
    for i in 37..42 {
        let tensor = Tensor::F32 { shape: vec![70], data: stack.layer(i).to_vec() };
        let (status, _) = post(
            &addr,
            &format!("/v1/sessions/tile-r/ingest?t={}", stack.time_axis[i]),
            "application/octet-stream",
            &bten_to_bytes(&tensor).unwrap(),
        );
        assert_eq!(status, 200, "layer {i}");
    }
    // graceful stop over the wire, like an operator would
    let (status, _) = post(&addr, "/shutdown", "", &[]);
    assert_eq!(status, 200);
    server.wait().unwrap();

    // second server, same state dir: the session is back, resumes
    let server = start_server(Some(dir.clone()), 4, 1);
    let addr = server.addr().to_string();
    let (status, body) = get(&addr, "/v1/sessions");
    assert_eq!(status, 200);
    let names = parse_json(&body);
    let names = names.get("sessions").unwrap().as_arr().unwrap();
    assert_eq!(names.len(), 1);
    assert_eq!(names[0].as_str().unwrap(), "tile-r");
    for i in 42..48 {
        let tensor = Tensor::F32 { shape: vec![70], data: stack.layer(i).to_vec() };
        let (status, _) = post(
            &addr,
            &format!("/v1/sessions/tile-r/ingest?t={}", stack.time_axis[i]),
            "application/octet-stream",
            &bten_to_bytes(&tensor).unwrap(),
        );
        assert_eq!(status, 200, "layer {i}");
    }

    let reference = BfastRunner::emulated(RunnerConfig::default())
        .unwrap()
        .run(
            &stack,
            &BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, lambda).unwrap(),
        )
        .unwrap()
        .map;
    let (status, body) = get(&addr, "/v1/sessions/tile-r/map");
    assert_eq!(status, 200);
    assert_maps_identical(&parse_map(&body), &reference, "resumed session vs fresh run");
    server.stop().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
