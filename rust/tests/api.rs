//! Front-door integration suite: the same [`AnalysisRequest`] must
//! yield **bit-identical** break maps through every entry point —
//! library execute, CLI flag parsing, and a wire submit to a live
//! server — round-trip exactly through its canonical JSON form, slice
//! pixel ranges consistently, and stop early when cancelled (both via
//! the in-process [`CancelToken`] and `DELETE /v1/runs/{id}`).

use bfast::api::{
    self, AnalysisRequest, CancelToken, EngineSpec, JobHandle, ParamSpec, SceneSource,
};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::json;
use bfast::params::BfastParams;
use bfast::raster::{io as rio, BreakMap, TimeStack};
use bfast::runtime::EmulatedDevice;
use bfast::serve::http::roundtrip;
use bfast::serve::{ServeConfig, Server};
use bfast::synth::ArtificialDataset;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Analysis shape shared by the tests: N=48, n=36, h=12, k=1.
fn params_new(n_total: usize) -> BfastParams {
    BfastParams::new(n_total, 36, 12, 1, 12.0, 0.05).unwrap()
}

fn param_spec() -> ParamSpec {
    ParamSpec {
        n_total: Some(48),
        n_hist: 36,
        h: 12,
        k: 1,
        freq: 12.0,
        alpha: 0.05,
        lambda: None,
    }
}

fn scene(m: usize, seed: u64) -> TimeStack {
    let mut data = ArtificialDataset::new(params_new(48), m, seed).generate();
    if m >= 8 {
        let d = data.stack.data_mut();
        for t in 0..48 {
            d[t * m] = f32::NAN; // dead pixel
        }
        for t in 10..14 {
            d[t * m + 3] = f32::NAN; // cloud hole
        }
    }
    data.stack
}

fn parse_json(body: &[u8]) -> json::Value {
    json::parse(std::str::from_utf8(body).unwrap().trim()).unwrap()
}

fn parse_map(body: &[u8]) -> BreakMap {
    let v = parse_json(body);
    let ints = |key: &str| -> Vec<i32> {
        v.get(key)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect()
    };
    let momax = v
        .get("momax")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect();
    BreakMap { breaks: ints("breaks"), first: ints("first"), momax }
}

fn assert_maps_identical(a: &BreakMap, b: &BreakMap, ctx: &str) {
    assert_eq!(a.breaks, b.breaks, "{ctx}: breaks differ");
    assert_eq!(a.first, b.first, "{ctx}: first differ");
    assert_eq!(a.momax.len(), b.momax.len(), "{ctx}: momax length");
    for (px, (x, y)) in a.momax.iter().zip(&b.momax).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: momax differs at px {px}: {x} vs {y}");
    }
}

fn get(addr: &str, path: &str) -> (u16, Vec<u8>) {
    roundtrip(addr, "GET", path, "", &[]).unwrap()
}

fn wait_job(addr: &str, id: u64) -> json::Value {
    for _ in 0..3000 {
        let (status, body) = get(addr, &format!("/v1/runs/{id}"));
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let v = parse_json(&body);
        match v.get("status").unwrap().as_str().unwrap() {
            "done" => return v,
            "failed" | "cancelled" => {
                panic!("job {id} ended early: {}", String::from_utf8_lossy(&body))
            }
            _ => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    panic!("job {id} did not finish in time");
}

/// Acceptance: CLI flags, a library call, and a wire submit of the
/// same request produce bit-identical break maps.
#[test]
fn front_door_equivalence_cli_library_wire() {
    let stack = scene(150, 31);
    let path = std::env::temp_dir().join(format!("bfast_api_eq_{}.bsq", std::process::id()));
    rio::write_stack(&path, &stack).unwrap();

    // 1. library: an in-memory request, executed directly
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack.clone()));
    req.params = param_spec();
    req.engine = EngineSpec::Emulated;
    let lib_map = req.execute(&JobHandle::new()).unwrap().map;

    // 2. CLI: the exact flags→request parsing `bfast run` uses
    let args: Vec<String> = [
        "--input",
        path.to_str().unwrap(),
        "--engine",
        "emulated",
        "--n-total",
        "48",
        "--n-hist",
        "36",
        "--h",
        "12",
        "--k",
        "1",
        "--freq",
        "12",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let cli_req = api::run_request_from_args(&args).unwrap();
    let cli_map = cli_req.execute(&JobHandle::new()).unwrap().map;

    // 3. wire: POST the canonical JSON to a live server
    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().to_string();
    let (status, body) = roundtrip(
        &addr,
        "POST",
        "/v1/runs",
        "application/json",
        req.to_json_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let id = parse_json(&body).get("job").unwrap().as_usize().unwrap() as u64;
    wait_job(&addr, id);
    let (status, body) = get(&addr, &format!("/v1/runs/{id}/map"));
    assert_eq!(status, 200);
    let wire_map = parse_map(&body);

    // the wire refuses path sources: a remote caller must not be able
    // to make the server read local files
    let mut path_req = req.clone();
    path_req.source = SceneSource::Path("/etc/hosts".into());
    let (status, _) = roundtrip(
        &addr,
        "POST",
        "/v1/runs",
        "application/json",
        path_req.to_json_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 400, "path scene source must be rejected on the wire");
    server.stop().unwrap();
    std::fs::remove_file(&path).ok();

    assert_maps_identical(&lib_map, &cli_map, "library vs CLI front door");
    assert_maps_identical(&lib_map, &wire_map, "library vs wire front door");
}

/// The wire form is a fixed point: serialize → parse → serialize is
/// byte-identical, NaN observations and all.
#[test]
fn wire_form_is_a_fixed_point_including_nans() {
    let mut req = AnalysisRequest::new(SceneSource::Inline(scene(12, 5)));
    req.params = param_spec();
    req.params.lambda = Some(3.25);
    req.chunking.pixel_range = Some((2, 10));
    req.outputs.timings = true;
    let text = req.to_json_string();
    let back = AnalysisRequest::from_json_str(&text).unwrap();
    assert_eq!(back.to_json_string(), text);
}

/// Acceptance: a cancelled run observably stops before completing all
/// chunks — the CancelToken is honoured at chunk boundaries.
#[test]
fn cancelled_run_stops_before_completing_all_chunks() {
    let params = params_new(48);
    let stack = scene(256, 9); // 32 chunks at m_chunk = 8
    let runner = BfastRunner::new(
        Box::new(EmulatedDevice::new().with_m_chunk(8)),
        RunnerConfig::default(),
    )
    .unwrap();
    let cancel = CancelToken::new();
    let executed = AtomicUsize::new(0);
    let err = runner
        .run_with_progress(&stack, &params, &cancel, |done, total| {
            assert_eq!(total, 32);
            executed.store(done, Ordering::SeqCst);
            if done == 1 {
                cancel.cancel(); // cancel mid-run, from the progress hook
            }
        })
        .unwrap_err();
    assert!(api::is_cancelled(&err), "expected a cancellation, got: {err:#}");
    let done = executed.load(Ordering::SeqCst);
    assert!(
        done >= 1 && done < 32,
        "cancelled run must stop early, but executed {done}/32 chunks"
    );

    // an already-cancelled token refuses to start at all
    let pre = CancelToken::new();
    pre.cancel();
    let err = runner
        .run_with_progress(&stack, &params, &pre, |_, _| panic!("must not execute"))
        .unwrap_err();
    assert!(api::is_cancelled(&err));

    // and an untouched token runs to completion as before
    let full = runner.run(&stack, &params).unwrap();
    assert_eq!(full.chunks, 32);
}

/// `pixel_range` in the request equals slicing the scene by hand —
/// the partitioning contract a sharding coordinator relies on.
#[test]
fn pixel_range_request_matches_manual_slice() {
    let stack = scene(120, 17);
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack.clone()));
    req.params = param_spec();
    req.engine = EngineSpec::Emulated;
    req.chunking.pixel_range = Some((25, 80));
    let ranged = req.execute(&JobHandle::new()).unwrap();
    assert_eq!(ranged.map.len(), 55);

    let manual = BfastRunner::emulated(RunnerConfig::default())
        .unwrap()
        .run(&stack.slice_pixels(25, 80), &params_new(48))
        .unwrap();
    assert_maps_identical(&ranged.map, &manual.map, "pixel_range vs manual slice");
}

/// `DELETE /v1/runs/{id}` over the wire: a queued job is withdrawn and
/// lands in the `cancelled` state; repeat deletes 409, unknown ids 404.
#[test]
fn wire_cancel_via_delete() {
    let big = rio::stack_to_bytes(&scene(60_000, 3));
    const PQ: &str = "?n-hist=36&h=12&k=1&freq=12&alpha=0.05";

    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        job_workers: 1,
        queue_capacity: 8,
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().to_string();

    let submit = |body: &[u8]| -> u64 {
        let (status, resp) = roundtrip(
            &addr,
            "POST",
            &format!("/v1/runs{PQ}"),
            "application/octet-stream",
            body,
        )
        .unwrap();
        assert_eq!(status, 202, "{}", String::from_utf8_lossy(&resp));
        parse_json(&resp).get("job").unwrap().as_usize().unwrap() as u64
    };

    // the first big job occupies the single worker; the second waits
    let running = submit(&big);
    let victim = submit(&big);

    let (status, body) = roundtrip(&addr, "DELETE", &format!("/v1/runs/{victim}"), "", &[]).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));

    // the victim reaches the cancelled state without running its chunks
    let mut cancelled = false;
    for _ in 0..3000 {
        let (status, body) = get(&addr, &format!("/v1/runs/{victim}"));
        assert_eq!(status, 200);
        let v = parse_json(&body);
        match v.get("status").unwrap().as_str().unwrap() {
            "cancelled" => {
                cancelled = true;
                break;
            }
            "done" => panic!("victim ran to completion despite the DELETE"),
            _ => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    assert!(cancelled, "victim never reached the cancelled state");

    // terminal-state semantics
    let (status, _) = roundtrip(&addr, "DELETE", &format!("/v1/runs/{victim}"), "", &[]).unwrap();
    assert_eq!(status, 409, "cancelling a cancelled job");
    let (status, _) = roundtrip(&addr, "DELETE", "/v1/runs/9999", "", &[]).unwrap();
    assert_eq!(status, 404, "cancelling an unknown job");
    let (status, _) = get(&addr, &format!("/v1/runs/{victim}/map"));
    assert_eq!(status, 409, "map of a cancelled job");

    // the surviving job is unaffected
    wait_job(&addr, running);

    let (status, body) = get(&addr, "/metrics");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text.contains("bfast_jobs_cancelled 1"), "{text}");
    assert!(text.contains("bfast_finished_records_cap"), "{text}");
    server.stop().unwrap();
}

/// The typed back door: `GET /v1/runs/{id}/result` serves the
/// canonical v1 envelope, parseable into an [`api::AnalysisResult`]
/// bit-identical to the library's own `execute` — and the wire bytes
/// are a serialization fixed point.
#[test]
fn wire_result_envelope_matches_library_execute() {
    let stack = scene(80, 41);
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = param_spec();
    let lib = req.execute(&JobHandle::new()).unwrap();

    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().to_string();
    let (status, body) = roundtrip(
        &addr,
        "POST",
        "/v1/runs",
        "application/json",
        req.to_json_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let id = parse_json(&body).get("job").unwrap().as_usize().unwrap() as u64;
    wait_job(&addr, id);

    let (status, body) = get(&addr, &format!("/v1/runs/{id}/result"));
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let text = std::str::from_utf8(&body).unwrap().trim();
    let wire = api::AnalysisResult::from_json_str(text).unwrap();
    assert_maps_identical(&wire.map, &lib.map, "wire result vs library execute");
    assert_eq!(wire.params, lib.params, "resolved params must travel exactly");
    assert_eq!(wire.chunks, lib.chunks);
    assert_eq!(wire.engine, lib.engine);
    // parse → serialize reproduces the served bytes
    assert_eq!(wire.to_json_string(), text);

    // unknown jobs 404; sugar and canonical routes serve the same map
    let (status, _) = get(&addr, "/v1/runs/999/result");
    assert_eq!(status, 404);
    server.stop().unwrap();
}

/// A `SessionInit` posted as JSON primes the same session the raw
/// `.bsq` + query form does (summary fields line up).
#[test]
fn session_init_json_matches_query_form() {
    let stack = scene(40, 23);
    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr().to_string();

    let init = api::SessionInit {
        source: SceneSource::Inline(stack.clone()),
        params: ParamSpec { n_total: None, ..param_spec() },
        init_layers: 37,
    };
    let (status, body) = roundtrip(
        &addr,
        "POST",
        "/v1/sessions/json-tile",
        "application/json",
        init.to_json().to_string_compact().as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 201, "{}", String::from_utf8_lossy(&body));
    let a = parse_json(&body);

    let (status, body) = roundtrip(
        &addr,
        "POST",
        "/v1/sessions/query-tile?n-hist=36&h=12&k=1&freq=12&alpha=0.05&init-layers=37",
        "application/octet-stream",
        &rio::stack_to_bytes(&stack),
    )
    .unwrap();
    assert_eq!(status, 201, "{}", String::from_utf8_lossy(&body));
    let b = parse_json(&body);

    for key in ["pixels", "layers_seen", "n_hist", "h", "k", "breaks"] {
        assert_eq!(
            a.get(key).unwrap().as_usize().unwrap(),
            b.get(key).unwrap().as_usize().unwrap(),
            "summary field {key}"
        );
    }
    assert_eq!(
        a.get("lambda").unwrap().as_f64().unwrap().to_bits(),
        b.get("lambda").unwrap().as_f64().unwrap().to_bits(),
        "derived lambda"
    );
    server.stop().unwrap();
}
