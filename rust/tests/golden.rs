//! Golden-vector tests: every rust implementation against the python
//! float64 oracle (`python/compile/kernels/ref.py`, exported by
//! `aot.py --golden` into `artifacts/golden/`).

use bfast::params::BfastParams;
use bfast::pixel::{DirectBfast, NaiveBfast};
use bfast::cpu::FusedCpuBfast;
use bfast::raster::TimeStack;
use bfast::runtime::bten::{read_bten, Tensor};
use std::path::PathBuf;

struct Golden {
    params: BfastParams,
    t: Vec<f64>,
    y: Vec<f64>, // (N, m) row-major
    beta: Vec<f64>,
    mo: Vec<f64>,
    momax: Vec<f64>,
    breaks: Vec<i32>,
    first: Vec<i32>,
    m: usize,
}

fn load() -> Option<Golden> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden");
    if !dir.join("case0.json").exists() {
        eprintln!("SKIP golden tests: run `make artifacts` first");
        return None;
    }
    let meta = bfast::json::parse_file(dir.join("case0.json")).unwrap();
    let g = |k: &str| meta.get(k).unwrap().as_f64().unwrap();
    let params = BfastParams::with_lambda(
        g("N") as usize,
        g("n") as usize,
        g("h") as usize,
        g("k") as usize,
        g("f"),
        0.05,
        g("lam"),
    )
    .unwrap();
    let rd = |name: &str| read_bten(dir.join(format!("case0_{name}.bten"))).unwrap();
    let as_i32 = |t: &Tensor| t.as_i32().unwrap().to_vec();
    Some(Golden {
        m: g("m") as usize,
        params,
        t: rd("t").as_f64_vec(),
        y: rd("y").as_f64_vec(),
        beta: rd("beta").as_f64_vec(),
        mo: rd("mo").as_f64_vec(),
        momax: rd("momax").as_f64_vec(),
        breaks: as_i32(&rd("breaks")),
        first: as_i32(&rd("first")),
    })
}

fn stack_of(g: &Golden) -> TimeStack {
    let data: Vec<f32> = g.y.iter().map(|&v| v as f32).collect();
    TimeStack::from_vec(g.params.n_total, g.m, data)
        .unwrap()
        .with_time_axis(g.t.clone())
        .unwrap()
}

#[test]
fn direct_matches_python_oracle() {
    let Some(g) = load() else { return };
    let d = DirectBfast::new(g.params.clone(), &g.t).unwrap();
    let n_mon = g.params.n_monitor();
    for px in 0..g.m {
        let y: Vec<f64> = (0..g.params.n_total).map(|t| g.y[t * g.m + px]).collect();
        // beta
        let beta = d.fit_pixel(&y).unwrap();
        for (j, &b) in beta.iter().enumerate() {
            let want = g.beta[j * g.m + px];
            assert!((b - want).abs() < 1e-8, "px {px} beta[{j}]: {b} vs {want}");
        }
        // full mosum process
        let res = d.run_pixel(&y).unwrap();
        for i in 0..n_mon {
            let want = g.mo[i * g.m + px];
            assert!(
                (res.mosum[i] - want).abs() < 1e-8,
                "px {px} mo[{i}]: {} vs {want}",
                res.mosum[i]
            );
        }
        assert_eq!(res.scan.has_break as i32, g.breaks[px], "px {px} break");
        assert_eq!(res.scan.first, g.first[px], "px {px} first");
        assert!((res.scan.momax - g.momax[px]).abs() < 1e-8, "px {px} momax");
    }
}

#[test]
fn naive_matches_python_oracle() {
    let Some(g) = load() else { return };
    let stack = stack_of(&g);
    // f32 storage rounds the inputs; compare breaks/first exactly and
    // momax with an f32-scale tolerance.
    let map = NaiveBfast::new(g.params.clone()).run(&stack).unwrap();
    assert_eq!(map.breaks, g.breaks);
    assert_eq!(map.first, g.first);
    for (a, b) in map.momax.iter().zip(&g.momax) {
        assert!((*a as f64 - b).abs() < 5e-3, "{a} vs {b}");
    }
}

#[test]
fn fused_cpu_matches_python_oracle() {
    let Some(g) = load() else { return };
    let stack = stack_of(&g);
    let (map, _) = FusedCpuBfast::new(g.params.clone(), &g.t)
        .unwrap()
        .run(&stack)
        .unwrap();
    assert_eq!(map.breaks, g.breaks);
    assert_eq!(map.first, g.first);
    for (a, b) in map.momax.iter().zip(&g.momax) {
        assert!((*a as f64 - b).abs() < 5e-3, "{a} vs {b}");
    }
}
