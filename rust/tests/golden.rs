//! Golden-vector tests: every rust implementation against the python
//! float64 oracle (`python/compile/kernels/ref.py`).
//!
//! Two fixture sources are combined:
//!
//! * `tests/data/golden/` — small committed cases emitted by
//!   `python/compile/golden_fixtures.py` (breaking, stable, gappy).
//!   These are always present, so the golden suite runs in offline CI
//!   instead of self-skipping.
//! * `artifacts/golden/` — the larger vectors from `aot.py --golden`,
//!   picked up in addition whenever an artifact build exists.
//!
//! Gappy cases store `y` raw (NaN gaps included); the oracle ran on
//! the forward/backward-filled series, so the rust side applies its
//! own fill first — which also pins that both fills agree. An
//! entirely-missing pixel must produce the defined no-break result
//! (breaks=0, first=-1, momax=0) everywhere.

use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::pixel::{DirectBfast, NaiveBfast};
use bfast::raster::TimeStack;
use bfast::runtime::bten::{read_bten, Tensor};
use std::path::{Path, PathBuf};

struct Golden {
    label: String,
    params: BfastParams,
    t: Vec<f64>,
    /// (N, m) row-major, raw — NaN marks missing observations.
    y: Vec<f64>,
    beta: Vec<f64>,
    mo: Vec<f64>,
    momax: Vec<f64>,
    breaks: Vec<i32>,
    first: Vec<i32>,
    m: usize,
}

fn load_case(dir: &Path, idx: usize) -> Golden {
    let meta = bfast::json::parse_file(dir.join(format!("case{idx}.json"))).unwrap();
    let g = |k: &str| meta.get(k).unwrap().as_f64().unwrap();
    let name = meta
        .try_get("name")
        .and_then(|v| v.as_str().ok())
        .unwrap_or("unnamed")
        .to_string();
    let params = BfastParams::with_lambda(
        g("N") as usize,
        g("n") as usize,
        g("h") as usize,
        g("k") as usize,
        g("f"),
        0.05,
        g("lam"),
    )
    .unwrap();
    let rd = |tname: &str| read_bten(dir.join(format!("case{idx}_{tname}.bten"))).unwrap();
    let as_i32 = |t: &Tensor| t.as_i32().unwrap().to_vec();
    Golden {
        label: format!("{}/case{idx} ({name})", dir.display()),
        m: g("m") as usize,
        params,
        t: rd("t").as_f64_vec(),
        y: rd("y").as_f64_vec(),
        beta: rd("beta").as_f64_vec(),
        mo: rd("mo").as_f64_vec(),
        momax: rd("momax").as_f64_vec(),
        breaks: as_i32(&rd("breaks")),
        first: as_i32(&rd("first")),
    }
}

/// All available cases: the committed in-tree fixtures (mandatory)
/// plus any artifact-backed ones.
fn load_all() -> Vec<Golden> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut cases = Vec::new();
    for (dir, required) in
        [(root.join("tests/data/golden"), true), (root.join("artifacts/golden"), false)]
    {
        let mut idx = 0;
        while dir.join(format!("case{idx}.json")).exists() {
            cases.push(load_case(&dir, idx));
            idx += 1;
        }
        assert!(
            !required || idx > 0,
            "committed golden fixtures missing from {} — run \
             python3 python/compile/golden_fixtures.py",
            dir.display()
        );
    }
    cases
}

fn stack_of(g: &Golden) -> TimeStack {
    let data: Vec<f32> = g.y.iter().map(|&v| v as f32).collect();
    TimeStack::from_vec(g.params.n_total, g.m, data)
        .unwrap()
        .with_time_axis(g.t.clone())
        .unwrap()
}

/// Forward/backward fill in f64 (the oracle-side gap handling; the
/// fixture values are f32-representable so this matches the rust f32
/// fill exactly).
fn fill_f64(y: &mut [f64]) {
    let mut last = f64::NAN;
    for v in y.iter_mut() {
        if v.is_nan() {
            if !last.is_nan() {
                *v = last;
            }
        } else {
            last = *v;
        }
    }
    let mut next = f64::NAN;
    for v in y.iter_mut().rev() {
        if v.is_nan() {
            if !next.is_nan() {
                *v = next;
            }
        } else {
            next = *v;
        }
    }
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a.is_nan() && b.is_nan()) || (a - b).abs() < tol
}

#[test]
fn golden_fixtures_present_in_tree() {
    // the offline suite must never be empty again
    let n_cases = load_all().len();
    assert!(n_cases >= 3, "expected >= 3 committed cases, found {n_cases}");
}

#[test]
fn direct_matches_python_oracle() {
    for g in load_all() {
        let d = DirectBfast::new(g.params.clone(), &g.t).unwrap();
        let n_mon = g.params.n_monitor();
        for px in 0..g.m {
            let mut y: Vec<f64> =
                (0..g.params.n_total).map(|t| g.y[t * g.m + px]).collect();
            fill_f64(&mut y);
            // beta
            let beta = d.fit_pixel(&y).unwrap();
            for (j, &b) in beta.iter().enumerate() {
                let want = g.beta[j * g.m + px];
                assert!(
                    close(b, want, 1e-8),
                    "{} px {px} beta[{j}]: {b} vs {want}",
                    g.label
                );
            }
            // full mosum process
            let res = d.run_pixel(&y).unwrap();
            for i in 0..n_mon {
                let want = g.mo[i * g.m + px];
                assert!(
                    close(res.mosum[i], want, 1e-8),
                    "{} px {px} mo[{i}]: {} vs {want}",
                    g.label,
                    res.mosum[i]
                );
            }
            assert_eq!(
                res.scan.has_break as i32, g.breaks[px],
                "{} px {px} break",
                g.label
            );
            assert_eq!(res.scan.first, g.first[px], "{} px {px} first", g.label);
            assert!(
                close(res.scan.momax, g.momax[px], 1e-8),
                "{} px {px} momax: {} vs {}",
                g.label,
                res.scan.momax,
                g.momax[px]
            );
        }
    }
}

#[test]
fn naive_matches_python_oracle() {
    for g in load_all() {
        let mut stack = stack_of(&g);
        bfast::fill::fill_stack(&mut stack, 4);
        // f32 storage rounds intermediates; compare breaks/first
        // exactly and momax with an f32-scale tolerance.
        let map = NaiveBfast::new(g.params.clone()).run(&stack).unwrap();
        assert_eq!(map.breaks, g.breaks, "{} breaks", g.label);
        assert_eq!(map.first, g.first, "{} first", g.label);
        for (px, (a, b)) in map.momax.iter().zip(&g.momax).enumerate() {
            assert!(close(*a as f64, *b, 5e-3), "{} px {px}: {a} vs {b}", g.label);
        }
    }
}

#[test]
fn fused_cpu_matches_python_oracle() {
    for g in load_all() {
        let mut stack = stack_of(&g);
        bfast::fill::fill_stack(&mut stack, 4);
        let (map, _) = FusedCpuBfast::new(g.params.clone(), &g.t)
            .unwrap()
            .run(&stack)
            .unwrap();
        assert_eq!(map.breaks, g.breaks, "{} breaks", g.label);
        assert_eq!(map.first, g.first, "{} first", g.label);
        for (px, (a, b)) in map.momax.iter().zip(&g.momax).enumerate() {
            assert!(close(*a as f64, *b, 5e-3), "{} px {px}: {a} vs {b}", g.label);
        }
    }
}

#[test]
fn emulated_pipeline_matches_python_oracle() {
    // the full coordinated pipeline, raw (gappy) input: staging fills
    for g in load_all() {
        let stack = stack_of(&g);
        let runner = BfastRunner::emulated(RunnerConfig::default()).unwrap();
        let res = runner.run(&stack, &g.params).unwrap();
        assert_eq!(res.map.breaks, g.breaks, "{} breaks", g.label);
        assert_eq!(res.map.first, g.first, "{} first", g.label);
        for (px, (a, b)) in res.map.momax.iter().zip(&g.momax).enumerate() {
            assert!(close(*a as f64, *b, 5e-3), "{} px {px}: {a} vs {b}", g.label);
        }
    }
}
