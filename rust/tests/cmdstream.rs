//! Command-stream integration: the cross-backend grid of the recorded
//! `.bcmd` path. A stream recorded from a scene and replayed through
//! [`ReplayExecutor`] must reproduce the fused CPU engine **bitwise**
//! across chunk widths, cloud-hole gaps and dead (all-NaN) pixels;
//! the wire form must be a lossless fixed point; and damaged streams
//! must fail closed before any op executes.

use bfast::api::{AnalysisRequest, EngineSpec, JobHandle, ParamSpec, SceneSource};
use bfast::cmd::{record_stream, replay_to_results, CmdStream, RecordJob};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::raster::{BreakMap, TimeStack};
use bfast::synth::ArtificialDataset;

/// f32-exact parameters (integer-exact λ and freq) so the fused f64
/// engine and the f32 chunk contract agree bitwise.
fn params() -> BfastParams {
    BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 2.5).unwrap()
}

/// Seeded scene with cloud holes on every 7th pixel and pixel 0 fully
/// dead — the missing-data shapes of the paper's footnote 2.
fn gappy_scene(m: usize, seed: u64) -> TimeStack {
    let p = params();
    let mut stack = ArtificialDataset::new(p.clone(), m, seed).generate().stack;
    for px in (0..m).step_by(7) {
        let t = 1 + px % (p.n_total - 2);
        stack.data_mut()[t * m + px] = f32::NAN;
    }
    for t in 0..p.n_total {
        stack.data_mut()[t * m] = f32::NAN;
    }
    stack
}

/// The reference run: gap-fill host-side (per-pixel arithmetic is
/// exactly the recorded `fill_columns` op's), then the fused CPU
/// engine scene-wide.
fn fused_reference(stack: &TimeStack) -> BreakMap {
    let mut filled = stack.clone();
    bfast::fill::fill_stack(&mut filled, 2);
    let (map, _) = FusedCpuBfast::new(params(), &filled.time_axis).unwrap().run(&filled).unwrap();
    map
}

fn assert_bitwise(got: &BreakMap, want: &BreakMap, what: &str) {
    assert_eq!(got.breaks, want.breaks, "{what}: breaks");
    assert_eq!(got.first, want.first, "{what}: first");
    for (i, (a, b)) in got.momax.iter().zip(&want.momax).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what} px {i}: momax bits");
    }
}

#[test]
fn replay_matches_fused_cpu_across_chunk_widths_on_a_gappy_scene() {
    let p = params();
    let stack = gappy_scene(333, 11);
    let want = fused_reference(&stack);
    // widths below, straddling, and beyond the scene's pixel count
    for mc in [64usize, 301, 1024] {
        let job = RecordJob { tag: "grid".into(), stack: &stack, params: &p };
        let stream = record_stream(&[job], mc, true).unwrap();
        let res = replay_to_results(&stream).unwrap();
        assert_eq!(res.len(), 1);
        assert_eq!(res[0].chunks, 333usize.div_ceil(mc), "m_chunk={mc}: chunks");
        assert_bitwise(&res[0].map, &want, &format!("m_chunk={mc}"));
    }
}

#[test]
fn replay_survives_a_fully_dead_scene() {
    // every observation missing: fill leaves the series NaN and the
    // kernels must carry that through without ever flagging a break
    let p = params();
    let mut stack = ArtificialDataset::new(p.clone(), 40, 2).generate().stack;
    for v in stack.data_mut().iter_mut() {
        *v = f32::NAN;
    }
    let want = fused_reference(&stack);
    for mc in [64usize, 301, 1024] {
        let job = RecordJob { tag: "dead".into(), stack: &stack, params: &p };
        let stream = record_stream(&[job], mc, true).unwrap();
        let res = replay_to_results(&stream).unwrap();
        assert_bitwise(&res[0].map, &want, &format!("all-NaN m_chunk={mc}"));
        assert_eq!(res[0].map.break_count(), 0, "dead pixels never break");
    }
}

#[test]
fn bcmd_wire_form_is_a_lossless_fixed_point() {
    let p = params();
    let stack = gappy_scene(97, 3);
    let job = RecordJob { tag: "wire".into(), stack: &stack, params: &p };
    let stream = record_stream(&[job], 301, true).unwrap();

    let bytes = stream.encode();
    let decoded = CmdStream::decode(&bytes).unwrap();
    assert_eq!(decoded.encode(), bytes, "encode -> decode -> encode fixed point");

    // and the round-trip changes nothing observable: identical envelopes
    let a = replay_to_results(&stream).unwrap();
    let b = replay_to_results(&decoded).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.to_json_string(), y.to_json_string(), "replay envelope drifted");
    }
}

#[test]
fn damaged_streams_fail_closed() {
    let p = params();
    let stack = gappy_scene(30, 4);
    let job = RecordJob { tag: "dmg".into(), stack: &stack, params: &p };
    let bytes = record_stream(&[job], 16, true).unwrap().encode();

    // truncation anywhere — header, slot table, op payload, last byte
    for cut in [0, 3, 9, bytes.len() / 2, bytes.len() - 1] {
        assert!(CmdStream::decode(&bytes[..cut]).is_err(), "truncated at {cut} must fail");
    }

    // wrong magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    let err = CmdStream::decode(&bad).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");

    // future format version
    let mut bad = bytes.clone();
    bad[4] = 0xee;
    let err = CmdStream::decode(&bad).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    // trailing garbage after a well-formed stream
    let mut bad = bytes.clone();
    bad.push(0);
    assert!(CmdStream::decode(&bad).is_err(), "trailing bytes must fail");
}

#[test]
fn cmd_engine_through_the_api_matches_emulated_bitwise() {
    // `--engine cmd` is a first-class backend: the same AnalysisRequest
    // run through the command-stream executor and the emulated device
    // must agree bitwise, m_chunk override included
    let p = params();
    let stack = gappy_scene(120, 6);
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = ParamSpec::from_params(&p);
    req.chunking.m_chunk = Some(48);

    req.engine = EngineSpec::Cmd;
    let via_cmd = req.execute(&JobHandle::new()).unwrap();
    assert!(via_cmd.engine.starts_with("cmd replay"), "engine label: {}", via_cmd.engine);

    req.engine = EngineSpec::Emulated;
    let via_emu = req.execute(&JobHandle::new()).unwrap();
    assert_eq!(via_cmd.chunks, via_emu.chunks, "same chunk plan");
    assert_bitwise(&via_cmd.map, &via_emu.map, "cmd vs emulated");
}
