//! Cross-backend equivalence (the paper's Fig. 2 implementations):
//! the coordinated [`EmulatedDevice`] pipeline, the per-pixel
//! [`DirectBfast`] reference and the fused multi-core
//! [`FusedCpuBfast`] must agree on break maps for seeded synthetic
//! scenes — tolerance-based on the continuous statistic, exact on the
//! discrete outputs.

use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::pixel::DirectBfast;
use bfast::synth::ArtificialDataset;

fn params() -> BfastParams {
    BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 2.5).unwrap()
}

#[test]
fn three_implementations_agree_on_artificial_scene() {
    let p = params();
    let data = ArtificialDataset::new(p.clone(), 1337, 5).generate();

    // 1. coordinated emulated pipeline (chunked, staged, padded)
    let runner = BfastRunner::emulated(RunnerConfig::default()).unwrap();
    let res = runner.run(&data.stack, &p).unwrap();

    // 2. fused multi-core CPU engine (scene-wide)
    let (cpu_map, _) = FusedCpuBfast::new(p.clone(), &data.stack.time_axis)
        .unwrap()
        .run(&data.stack)
        .unwrap();

    // 3. per-pixel f64 reference
    let direct_map = DirectBfast::new(p.clone(), &data.stack.time_axis)
        .unwrap()
        .run(&data.stack)
        .unwrap();

    // emulated and cpu share the f32 arithmetic: exact agreement
    assert_eq!(res.map.breaks, cpu_map.breaks, "emulated vs cpu breaks");
    assert_eq!(res.map.first, cpu_map.first, "emulated vs cpu first");
    // the f64 reference may flip boundary-grazing pixels: tolerance
    let mism = mismatches(&res.map.breaks, &direct_map.breaks);
    assert!(mism as f64 <= 0.001 * res.len() as f64, "emulated vs direct: {mism} flips");
    for (i, ((a, b), c)) in res
        .map
        .momax
        .iter()
        .zip(&cpu_map.momax)
        .zip(&direct_map.momax)
        .enumerate()
    {
        assert!((a - b).abs() < 1e-5, "px {i}: emulated {a} vs cpu {b}");
        assert!((a - c).abs() < 2e-3, "px {i}: emulated {a} vs direct {c}");
    }
}

fn mismatches(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

#[test]
fn agreement_holds_across_seeds_and_sizes() {
    let p = params();
    for (m, seed) in [(1usize, 0u64), (97, 1), (512, 2), (1025, 3)] {
        let data = ArtificialDataset::new(p.clone(), m, seed).generate();
        let runner = BfastRunner::emulated(RunnerConfig::default()).unwrap();
        let res = runner.run(&data.stack, &p).unwrap();
        let direct_map = DirectBfast::new(p.clone(), &data.stack.time_axis)
            .unwrap()
            .run(&data.stack)
            .unwrap();
        let mism = mismatches(&res.map.breaks, &direct_map.breaks);
        assert!(mism <= 1 + m / 1000, "m={m} seed={seed}: {mism} flips vs f64 reference");
    }
}

#[test]
fn detection_quality_matches_ground_truth_through_the_pipeline() {
    // Strong injected breaks: the full coordinated pipeline must find
    // them all (TPR = 1) with few false alarms — same contract the
    // per-pixel baseline pins in its unit tests.
    let p = BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 6.0).unwrap();
    let data = ArtificialDataset::new(p.clone(), 400, 1)
        .with_noise(0.005, 0.5)
        .generate();
    let runner = BfastRunner::emulated(RunnerConfig::default()).unwrap();
    let res = runner.run(&data.stack, &p).unwrap();
    let (tpr, fpr) = data.score(&res.map.breaks);
    assert_eq!(tpr, 1.0, "all injected breaks found");
    assert!(fpr < 0.2, "fpr {fpr}");
}

/// The optimised engines must agree **bitwise** — not just within
/// tolerance — regardless of how the coordinator slices the pixel
/// axis. Chunk geometry is pure bookkeeping: each pixel's arithmetic
/// is independent, so the tiled GEMM, the fused MOSUM+detect pass and
/// the emulated device path may not let tile or chunk boundaries leak
/// into the results. Uses a fig2-shaped scene with an f32-exact λ so
/// the emulated backend's f32 λ round-trip is lossless.
#[test]
fn optimized_engines_agree_bitwise_across_chunk_geometries() {
    use bfast::runtime::EmulatedDevice;

    let p = BfastParams::with_lambda(200, 100, 50, 3, 23.0, 0.05, 2.5).unwrap();
    let m = 777usize; // not a multiple of anything interesting
    let data = ArtificialDataset::new(p.clone(), m, 42).generate();

    let (cpu_map, _) = FusedCpuBfast::new(p.clone(), &data.stack.time_axis)
        .unwrap()
        .run(&data.stack)
        .unwrap();

    // the f64 per-pixel reference stays within the usual tolerance
    let direct_map = DirectBfast::new(p.clone(), &data.stack.time_axis)
        .unwrap()
        .run(&data.stack)
        .unwrap();
    let mism = mismatches(&cpu_map.breaks, &direct_map.breaks);
    assert!(mism as f64 <= 0.001 * m as f64, "cpu vs direct: {mism} flips");

    // chunk widths straddling m, the default, and an odd width
    for mc in [64usize, 301, 1024] {
        let runner = BfastRunner::new(
            Box::new(EmulatedDevice::new().with_m_chunk(mc)),
            RunnerConfig::default(),
        )
        .unwrap();
        let res = runner.run(&data.stack, &p).unwrap();
        assert_eq!(res.map.breaks, cpu_map.breaks, "m_chunk={mc}: breaks");
        assert_eq!(res.map.first, cpu_map.first, "m_chunk={mc}: first");
        for (i, (a, b)) in res.map.momax.iter().zip(&cpu_map.momax).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "m_chunk={mc} px {i}: momax bits");
        }
    }

    // the RunnerConfig override path must be equally invisible
    let runner = BfastRunner::emulated(RunnerConfig {
        m_chunk: Some(97),
        ..Default::default()
    })
    .unwrap();
    let res = runner.run(&data.stack, &p).unwrap();
    assert_eq!(res.chunks, m.div_ceil(97), "override reaches the chunk plan");
    assert_eq!(res.map.breaks, cpu_map.breaks, "m_chunk override: breaks");
    assert_eq!(res.map.first, cpu_map.first, "m_chunk override: first");
    for (i, (a, b)) in res.map.momax.iter().zip(&cpu_map.momax).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "override px {i}: momax bits");
    }
}
