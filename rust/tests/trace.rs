//! Flight-recorder pins, over real sockets: request ids echo through
//! every front door, the serve trace endpoint yields a valid Chrome
//! trace for a finished run, and — the distributed acceptance case — a
//! two-worker gateway run whose worker is murdered mid-run still
//! produces ONE merged trace: gateway spans plus both workers' spans
//! under a single request id, with the retry shard span parented under
//! the original (failed) shard span.

use bfast::api::{AnalysisRequest, ParamSpec, SceneSource};
use bfast::gateway::chaos::{ChaosProxy, Mode};
use bfast::gateway::{Gateway, GatewayConfig};
use bfast::json::{self, Value};
use bfast::params::BfastParams;
use bfast::raster::TimeStack;
use bfast::serve::http::{roundtrip, Client};
use bfast::serve::{ServeConfig, Server};
use bfast::synth::ArtificialDataset;
use std::time::{Duration, Instant};

fn params_new(n_total: usize) -> BfastParams {
    BfastParams::new(n_total, 36, 12, 1, 12.0, 0.05).unwrap()
}

fn param_spec() -> ParamSpec {
    ParamSpec {
        n_total: Some(48),
        n_hist: 36,
        h: 12,
        k: 1,
        freq: 12.0,
        alpha: 0.05,
        lambda: None,
    }
}

fn scene(m: usize, seed: u64) -> TimeStack {
    ArtificialDataset::new(params_new(48), m, seed).generate().stack
}

fn start_worker() -> Server {
    Server::start(ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() }).unwrap()
}

fn get(addr: &str, path: &str) -> (u16, Vec<u8>) {
    roundtrip(addr, "GET", path, "", &[]).unwrap()
}

fn parse_json(body: &[u8]) -> Value {
    json::parse(std::str::from_utf8(body).unwrap().trim()).unwrap()
}

fn wait_finished(addr: &str, id: u64, deadline: Duration) -> Value {
    let t0 = Instant::now();
    loop {
        let (status, body) = get(addr, &format!("/v1/runs/{id}"));
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let v = parse_json(&body);
        let s = v.get("status").unwrap().as_str().unwrap();
        if s == "done" || s == "failed" || s == "cancelled" {
            return v;
        }
        assert!(t0.elapsed() < deadline, "job {id} still {s} after {deadline:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn wait_alive(gw: &str, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, body) = get(gw, "/healthz");
        assert_eq!(status, 200);
        if parse_json(&body).get("workers_alive").unwrap().as_usize().unwrap() == want {
            return;
        }
        assert!(Instant::now() < deadline, "fleet never reached {want} live worker(s)");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn observe_mid_run(worker: &str) {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let (status, body) = get(worker, "/v1/runs");
        assert_eq!(status, 200);
        let mid = parse_json(&body).get("jobs").unwrap().as_arr().unwrap().iter().any(|j| {
            j.get("status").unwrap().as_str().unwrap() == "running"
                && j.get("progress").unwrap().as_f64().unwrap() > 0.0
        });
        if mid {
            return;
        }
        assert!(Instant::now() < deadline, "{worker}: no shard reached mid-run");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Wait until every job the worker has ever accepted is terminal —
/// after a rebalance the orphaned shard keeps running server-side, and
/// its trace is only fully flushed once its run span drops.
fn wait_all_terminal(worker: &str, deadline: Duration) {
    let t0 = Instant::now();
    loop {
        let (status, body) = get(worker, "/v1/runs");
        assert_eq!(status, 200);
        let all = parse_json(&body).get("jobs").unwrap().as_arr().unwrap().iter().all(|j| {
            matches!(
                j.get("status").unwrap().as_str().unwrap(),
                "done" | "failed" | "cancelled"
            )
        });
        if all {
            return;
        }
        assert!(t0.elapsed() < deadline, "{worker}: orphaned job never reached a terminal state");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Every trace event must carry the Chrome trace-event required keys;
/// returns the events array for further inspection.
fn check_chrome_shape(trace: &Value) -> Vec<Value> {
    let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "empty traceEvents");
    for ev in events {
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        ev.get("name").unwrap().as_str().unwrap();
        ev.get("pid").unwrap().as_f64().unwrap();
        ev.get("tid").unwrap().as_f64().unwrap();
        if ph == "X" {
            assert!(ev.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            assert!(ev.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        } else {
            assert_eq!(ph, "M", "unexpected phase {ph:?}");
        }
    }
    events.to_vec()
}

/// Serve front door: an `X-Request-Id` header is adopted, echoed in
/// the 202 body and the status JSON, and stamps the whole trace.
#[test]
fn serve_adopts_header_request_id_and_serves_a_chrome_trace() {
    let w = start_worker();
    let addr = w.addr().to_string();
    let rid = "cafef00ddeadbeef";

    let mut req = AnalysisRequest::new(SceneSource::Inline(scene(120, 7)));
    req.params = param_spec();
    let mut c = Client::connect_timeout(&addr, Duration::from_secs(10)).unwrap();
    let (status, _headers, body) = c
        .request_with_headers(
            "POST",
            "/v1/runs",
            "application/json",
            &[("X-Request-Id", rid)],
            req.to_json_string().as_bytes(),
        )
        .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let accepted = parse_json(&body);
    assert_eq!(accepted.get("request_id").unwrap().as_str().unwrap(), rid);
    let id = accepted.get("job").unwrap().as_usize().unwrap() as u64;

    let done = wait_finished(&addr, id, Duration::from_secs(60));
    assert_eq!(done.get("status").unwrap().as_str().unwrap(), "done");
    assert_eq!(done.get("request_id").unwrap().as_str().unwrap(), rid);

    let (status, body) = get(&addr, &format!("/v1/runs/{id}/trace"));
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let trace = parse_json(&body);
    assert_eq!(
        trace.get("otherData").unwrap().get("request_id").unwrap().as_str().unwrap(),
        rid
    );
    let events = check_chrome_shape(&trace);

    let run = events
        .iter()
        .find(|e| e.get("name").unwrap().as_str().unwrap() == "run")
        .expect("no run span in the serve trace");
    assert_eq!(run.get("args").unwrap().get("request_id").unwrap().as_str().unwrap(), rid);
    let chunks = events
        .iter()
        .filter(|e| e.get("name").unwrap().as_str().unwrap() == "chunk")
        .count();
    assert!(chunks > 0, "no chunk spans recorded");
    // the engine phases nest under the chunks: more spans than just
    // run + chunks means per-phase scopes made it into the ring
    assert!(
        events.len() > 1 + chunks,
        "expected phase spans beyond run + {chunks} chunk(s), got {} events",
        events.len()
    );

    w.stop().unwrap();
}

/// An unknown job is a 404, and a submit without any id gets one
/// minted (16 hex chars) at the front door.
#[test]
fn trace_endpoint_404s_and_ids_are_minted_when_absent() {
    let w = start_worker();
    let addr = w.addr().to_string();
    let (status, _) = get(&addr, "/v1/runs/9999/trace");
    assert_eq!(status, 404);

    let mut req = AnalysisRequest::new(SceneSource::Inline(scene(64, 9)));
    req.params = param_spec();
    let (status, body) =
        roundtrip(&addr, "POST", "/v1/runs", "application/json", req.to_json_string().as_bytes())
            .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let rid = parse_json(&body).get("request_id").unwrap().as_str().unwrap().to_string();
    assert_eq!(rid.len(), 16, "minted request id {rid:?} is not 16 hex chars");
    assert!(rid.chars().all(|c| c.is_ascii_hexdigit()), "minted request id {rid:?} not hex");

    w.stop().unwrap();
}

/// The acceptance pin: a 2-worker gateway run with one worker
/// black-holed mid-run produces ONE merged Chrome trace — gateway
/// spans (pid 1) plus both workers' spans (distinct pids) under the
/// submitter's request id, and the replacement shard span is parented
/// under the original failed shard span.
#[test]
fn killed_worker_run_yields_one_merged_trace_with_reparented_retry() {
    let w1 = start_worker();
    let w2 = start_worker();
    let proxy = ChaosProxy::start(&w2.addr().to_string()).unwrap();
    let proxy_addr = proxy.addr().to_string();
    let w1_addr = w1.addr().to_string();

    let cfg = GatewayConfig {
        addr: "127.0.0.1:0".into(),
        workers: vec![w1_addr.clone(), proxy_addr.clone()],
        poll: Duration::from_millis(5),
        sweep: Duration::from_millis(50),
        io_timeout: Duration::from_millis(500),
        heartbeat_timeout: Duration::from_secs(2),
        ..Default::default()
    };
    let gw = Gateway::start(cfg).unwrap();
    let gaddr = gw.addr().to_string();
    wait_alive(&gaddr, 2);

    let rid = "feedfacecafef00d";
    let mut req = AnalysisRequest::new(SceneSource::Inline(scene(100_000, 3)));
    req.params = param_spec();
    req.request_id = Some(rid.to_string());
    let (status, body) =
        roundtrip(&gaddr, "POST", "/v1/runs", "application/json", req.to_json_string().as_bytes())
            .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let accepted = parse_json(&body);
    assert_eq!(accepted.get("request_id").unwrap().as_str().unwrap(), rid);
    let id = accepted.get("job").unwrap().as_usize().unwrap() as u64;

    // the shard is provably executing on w2 before the link goes
    // half-open — then murder it
    observe_mid_run(&w2.addr().to_string());
    proxy.set_mode(Mode::Blackhole);
    proxy.kill_connections();

    let done = wait_finished(&gaddr, id, Duration::from_secs(300));
    assert_eq!(
        done.get("status").unwrap().as_str().unwrap(),
        "done",
        "{}",
        done.to_string_compact()
    );
    assert_eq!(done.get("request_id").unwrap().as_str().unwrap(), rid);

    // revive the link so the merge can reach the orphaned worker, and
    // wait for its shard to finish (its trace flushes on completion)
    proxy.set_mode(Mode::Forward);
    wait_all_terminal(&w2.addr().to_string(), Duration::from_secs(300));
    wait_all_terminal(&w1_addr, Duration::from_secs(300));

    let (status, body) = get(&gaddr, &format!("/v1/runs/{id}/trace"));
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let trace = parse_json(&body);
    let other = trace.get("otherData").unwrap();
    assert_eq!(other.get("request_id").unwrap().as_str().unwrap(), rid, "one request id");
    assert_eq!(
        other.get("workers_unreachable").unwrap().as_usize().unwrap(),
        0,
        "every placed shard's trace must be reachable after the revive"
    );
    assert!(other.get("workers_merged").unwrap().as_usize().unwrap() >= 3);
    let events = check_chrome_shape(&trace);

    // spans from the gateway AND both workers, in distinct process lanes
    let mut pids: Vec<u64> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
        .map(|e| e.get("pid").unwrap().as_f64().unwrap() as u64)
        .collect();
    pids.sort_unstable();
    pids.dedup();
    assert!(pids.contains(&1), "no gateway spans (pid 1) in {pids:?}");
    assert!(
        pids.iter().filter(|&&p| p > 1).count() >= 2,
        "expected spans from at least two worker lanes, got pids {pids:?}"
    );
    let lane_names: Vec<String> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "M")
        .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string())
        .collect();
    assert!(
        lane_names.iter().any(|n| n.contains(&w1_addr)),
        "no process lane for {w1_addr} in {lane_names:?}"
    );
    assert!(
        lane_names.iter().any(|n| n.contains(&proxy_addr)),
        "no process lane for the killed worker {proxy_addr} in {lane_names:?}"
    );

    // retry parenting: the attempt-2 shard span hangs off the failed
    // attempt-1 shard span, so the rescue reads as a child in the UI
    let shard_spans: Vec<&Value> = events
        .iter()
        .filter(|e| {
            e.get("pid").unwrap().as_f64().unwrap() as u64 == 1
                && e.get("name").unwrap().as_str().unwrap() == "shard"
        })
        .collect();
    assert!(shard_spans.len() >= 3, "expected >=3 shard spans, got {}", shard_spans.len());
    let span_field = |e: &Value, key: &str| -> u64 {
        e.get("args").unwrap().get(key).unwrap().as_f64().unwrap() as u64
    };
    let attempt = |e: &Value| -> String {
        e.get("args").unwrap().get("attempt").unwrap().as_str().unwrap().to_string()
    };
    let retry = shard_spans
        .iter()
        .find(|e| attempt(e) == "2")
        .expect("no attempt-2 (retry) shard span in the gateway trace");
    let parent = span_field(retry, "parent_id");
    let original = shard_spans
        .iter()
        .find(|e| span_field(e, "span_id") == parent)
        .unwrap_or_else(|| panic!("retry parent {parent} is not a shard span"));
    assert_eq!(attempt(original), "1", "retry must parent under the original placement");
    assert_eq!(
        original.get("args").unwrap().get("worker").unwrap().as_str().unwrap(),
        proxy_addr,
        "the retry's parent must be the shard placed on the killed worker"
    );

    gw.stop().unwrap();
    proxy.stop();
    w1.stop().unwrap();
    w2.stop().unwrap();
}
