//! Sharded fan-out suite — the acceptance contract of the shard
//! layer: `merge(split(req, k))` must be **bit-identical** to the
//! unsharded run (property-pinned for k ∈ {1, 2, 3, 7}, including
//! single-pixel shards, k > pixels, and a scene whose pixel count does
//! not divide evenly), and a real fan-out across ≥ 2 live-socket serve
//! workers must reproduce a direct `BfastRunner::run` bit-for-bit —
//! including when a worker is dead (shard retried on a survivor) and
//! when the aggregate handle is cancelled mid-run (DELETE fan-out).

use bfast::api::{
    self, AnalysisRequest, EngineSpec, JobHandle, ParamSpec, PartialResult, SceneSource,
};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::json;
use bfast::params::BfastParams;
use bfast::raster::{BreakMap, TimeStack};
use bfast::serve::http::roundtrip;
use bfast::serve::{ServeConfig, Server};
use bfast::shard::{self, ShardOptions};
use bfast::synth::ArtificialDataset;
use std::time::{Duration, Instant};

/// Analysis shape shared by every test: N=48, n=36, h=12, k=1.
fn params_new(n_total: usize) -> BfastParams {
    BfastParams::new(n_total, 36, 12, 1, 12.0, 0.05).unwrap()
}

fn param_spec() -> ParamSpec {
    ParamSpec {
        n_total: Some(48),
        n_hist: 36,
        h: 12,
        k: 1,
        freq: 12.0,
        alpha: 0.05,
        lambda: None,
    }
}

fn scene(m: usize, seed: u64) -> TimeStack {
    let mut data = ArtificialDataset::new(params_new(48), m, seed).generate();
    if m >= 8 {
        let d = data.stack.data_mut();
        for t in 0..48 {
            d[t * m] = f32::NAN; // dead pixel
        }
        for t in 10..14 {
            d[t * m + 3] = f32::NAN; // cloud hole
        }
    }
    data.stack
}

fn assert_maps_identical(a: &BreakMap, b: &BreakMap, ctx: &str) {
    assert_eq!(a.breaks, b.breaks, "{ctx}: breaks differ");
    assert_eq!(a.first, b.first, "{ctx}: first differ");
    assert_eq!(a.momax.len(), b.momax.len(), "{ctx}: momax length");
    for (px, (x, y)) in a.momax.iter().zip(&b.momax).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: momax differs at px {px}: {x} vs {y}");
    }
}

fn start_worker() -> Server {
    Server::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    })
    .unwrap()
}

fn fast_opts() -> ShardOptions {
    ShardOptions { poll: Duration::from_millis(5), ..Default::default() }
}

/// Satellite: `merge(split(req, k))` is bit-identical to the unsharded
/// run for k ∈ {1, 2, 3, 7} — across a single-pixel scene (shards
/// beyond the pixel count are omitted), a 5-pixel scene under k=7, and
/// a 101-pixel scene (with NaN holes) that no k divides evenly.
#[test]
fn merge_of_split_is_bit_identical_to_unsharded_run() {
    for &(m, seed) in &[(1usize, 5u64), (5, 9), (101, 17)] {
        let mut req = AnalysisRequest::new(SceneSource::Inline(scene(m, seed)));
        req.params = param_spec();
        req.engine = EngineSpec::Emulated;
        let whole = req.execute(&JobHandle::new()).unwrap();
        for k in [1usize, 2, 3, 7] {
            let shards = shard::split(&req, k).unwrap();
            assert_eq!(shards.len(), k.min(m), "m={m} k={k}");
            let parts: Vec<PartialResult> = shards
                .iter()
                .map(|s| {
                    let range = s.chunking.pixel_range.unwrap();
                    PartialResult::new(range, s.execute(&JobHandle::new()).unwrap()).unwrap()
                })
                .collect();
            let merged = PartialResult::assemble(parts)
                .unwrap()
                .into_full(m, None, None)
                .unwrap();
            assert_maps_identical(&merged.map, &whole.map, &format!("m={m} k={k}"));
            assert_eq!(merged.params, whole.params, "m={m} k={k}: params");
        }
    }
}

/// Splitting a request that already carries a pixel range partitions
/// *that* range, and the reassembly matches the unsharded ranged run.
#[test]
fn split_of_ranged_request_matches_ranged_run() {
    let mut req = AnalysisRequest::new(SceneSource::Inline(scene(60, 23)));
    req.params = param_spec();
    req.engine = EngineSpec::Emulated;
    req.chunking.pixel_range = Some((13, 44));
    let whole = req.execute(&JobHandle::new()).unwrap();
    let parts: Vec<PartialResult> = shard::split(&req, 3)
        .unwrap()
        .iter()
        .map(|s| {
            // shard ranges are absolute scene coordinates; the
            // assembled result lives in the ranged run's [0, 31) space
            let (a, b) = s.chunking.pixel_range.unwrap();
            assert!((13..=44).contains(&a) && a < b && b <= 44);
            PartialResult::new((a - 13, b - 13), s.execute(&JobHandle::new()).unwrap())
                .unwrap()
        })
        .collect();
    let merged = PartialResult::assemble(parts)
        .unwrap()
        .into_full(31, None, None)
        .unwrap();
    assert_maps_identical(&merged.map, &whole.map, "ranged split");
}

/// Acceptance: a sharded run across two real-socket serve workers is
/// bit-identical to a direct single-process `BfastRunner::run`, the
/// work actually lands on both workers, geometry is reattached, and
/// the aggregate handle ends at 100% progress.
#[test]
fn two_worker_sharded_run_matches_direct_run() {
    let stack = scene(150, 31).with_geometry(15, 10).unwrap();
    let reference = BfastRunner::emulated(RunnerConfig::default())
        .unwrap()
        .run(&stack, &params_new(48))
        .unwrap()
        .map;

    let w1 = start_worker();
    let w2 = start_worker();
    let workers = vec![w1.addr().to_string(), w2.addr().to_string()];
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = param_spec();
    let handle = JobHandle::new();
    let run = shard::run_sharded(&req, &workers, &fast_opts(), &handle).unwrap();

    assert_eq!(run.shards.len(), 2);
    let mut placed: Vec<&str> = run.shards.iter().map(|s| s.worker.as_str()).collect();
    placed.sort_unstable();
    let mut expected: Vec<&str> = workers.iter().map(|w| w.as_str()).collect();
    expected.sort_unstable();
    assert_eq!(placed, expected, "both workers must carry a shard");
    assert!(run.shards.iter().all(|s| s.attempts == 1));

    assert_maps_identical(&run.result.map, &reference, "sharded vs direct");
    assert_eq!((run.result.width, run.result.height), (Some(15), Some(10)));
    let (done, total) = handle.progress();
    assert_eq!(done, total);
    assert!(total >= 2, "aggregate progress should span both shards' chunks");

    w1.stop().unwrap();
    w2.stop().unwrap();
}

/// Acceptance: a shard placed on a dead worker is retried on a
/// surviving one, and the merged map is still bit-identical.
#[test]
fn failed_shard_retries_on_surviving_worker() {
    let stack = scene(120, 7);
    let reference = BfastRunner::emulated(RunnerConfig::default())
        .unwrap()
        .run(&stack, &params_new(48))
        .unwrap()
        .map;

    // a dead address: bind an ephemeral port, then drop the listener
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let live = start_worker();
    let workers = vec![dead.clone(), live.addr().to_string()];
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = param_spec();
    let run = shard::run_sharded(&req, &workers, &fast_opts(), &JobHandle::new()).unwrap();

    // shard 0's first placement (the dead worker) failed; the retry
    // landed on the survivor
    let rescued = run.shards.iter().find(|s| s.shard == 0).unwrap();
    assert_eq!(rescued.attempts, 2, "shard 0 must have been re-placed");
    assert_eq!(rescued.worker, live.addr().to_string());
    assert_maps_identical(&run.result.map, &reference, "retried shard fan-out");

    // with every worker dead, the failure is reported, not hung
    let err = shard::run_sharded(
        &req,
        &[dead],
        &ShardOptions { attempts: 2, ..fast_opts() },
        &JobHandle::new(),
    )
    .unwrap_err();
    assert!(!api::is_cancelled(&err), "dead fleet must fail, not cancel: {err:#}");

    live.stop().unwrap();
}

/// Acceptance: cancelling the aggregate `JobHandle` mid-run stops the
/// coordinator with `api::cancelled` and DELETE-fans-out to the
/// workers — their jobs reach the `cancelled` state without running to
/// completion.
#[test]
fn mid_run_cancellation_fans_out_deletes() {
    let stack = scene(100_000, 3); // ~49 chunks per worker at m_chunk 1024
    let w1 = start_worker();
    let w2 = start_worker();
    let workers = vec![w1.addr().to_string(), w2.addr().to_string()];
    let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
    req.params = param_spec();

    let handle = JobHandle::new();
    let coord_handle = handle.clone();
    let coordinator = std::thread::spawn(move || {
        shard::run_sharded(&req, &workers, &fast_opts(), &coord_handle)
    });

    // wait until *every* worker has its shard mid-run (≥ 1 chunk
    // executed), so the cancel provably interrupts in-flight work on
    // both, then pull the plug on the whole fan-out
    for addr in [w1.addr().to_string(), w2.addr().to_string()] {
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let (status, body) = roundtrip(&addr, "GET", "/v1/runs", "", &[]).unwrap();
            assert_eq!(status, 200);
            let v = json::parse(std::str::from_utf8(&body).unwrap().trim()).unwrap();
            let mid_run = v.get("jobs").unwrap().as_arr().unwrap().iter().any(|j| {
                j.get("status").unwrap().as_str().unwrap() == "running"
                    && j.get("progress").unwrap().as_f64().unwrap() > 0.0
            });
            if mid_run {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "{addr}: shard never started executing chunks"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    handle.cancel();
    let err = coordinator.join().unwrap().unwrap_err();
    assert!(api::is_cancelled(&err), "expected cancellation, got: {err:#}");

    // every worker's job lands in `cancelled` — never `done`
    for addr in [w1.addr().to_string(), w2.addr().to_string()] {
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let (status, body) = roundtrip(&addr, "GET", "/v1/runs", "", &[]).unwrap();
            assert_eq!(status, 200);
            let v = json::parse(std::str::from_utf8(&body).unwrap().trim()).unwrap();
            let jobs = v.get("jobs").unwrap().as_arr().unwrap();
            assert!(!jobs.is_empty(), "{addr}: shard job was never submitted");
            let states: Vec<&str> = jobs
                .iter()
                .map(|j| j.get("status").unwrap().as_str().unwrap())
                .collect();
            assert!(
                !states.contains(&"done"),
                "{addr}: a shard ran to completion despite the cancel ({states:?})"
            );
            if states.iter().all(|s| *s == "cancelled") {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "{addr}: jobs never reached cancelled ({states:?})"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    w1.stop().unwrap();
    w2.stop().unwrap();
}
