//! Integration tests for the `bfast bench` harness: the scenario grid
//! runs end to end at a tiny scale, the emitted report is a canonical
//! JSON fixed point, `diff` pairs results correctly, the chunk-width
//! tuner works, and the committed trajectory files stay loadable by
//! the current schema.

use bfast::bench::{
    self, BenchConfig, BenchReport, DiffRow, EngineResult, Fingerprint, ScenarioResult,
    ENGINE_EMULATED, ENGINE_FUSED, SCHEMA_VERSION, SOURCE_HARNESS,
};
use bfast::params::BfastParams;

/// Smallest honest config: scale floors m at 16, two exact trials.
fn tiny_cfg() -> BenchConfig {
    BenchConfig {
        scale: 1e-9,
        warmup: 0,
        trials: 2,
        scenarios: vec!["fig2".into()],
        engines: vec![ENGINE_FUSED.into(), ENGINE_EMULATED.into()],
    }
}

#[test]
fn harness_runs_fig2_and_emits_canonical_json() {
    let report = bench::run_all(&tiny_cfg()).unwrap();
    assert_eq!(report.version, SCHEMA_VERSION);
    assert_eq!(report.fingerprint.source, SOURCE_HARNESS);
    assert_eq!(report.fingerprint.trials, 2);
    assert_eq!(report.scenarios.len(), 1);

    let sc = &report.scenarios[0];
    assert_eq!(sc.scenario, "fig2");
    assert_eq!(sc.m, 16, "1e-9 scale must clamp to the floor");
    assert_eq!(sc.n_total, 200);
    assert_eq!(sc.seed, 42);
    let names: Vec<&str> = sc.engines.iter().map(|e| e.engine.as_str()).collect();
    assert_eq!(names, [ENGINE_FUSED, ENGINE_EMULATED]);
    for er in &sc.engines {
        assert_eq!(er.trials_ns.len(), 2, "{}: pinned trial count", er.engine);
        assert!(er.min_ns <= er.median_ns, "{}", er.engine);
        assert!(er.trials_ns.iter().all(|&t| t > 0), "{}", er.engine);
    }
    // the fused engine reports all five pipeline phases
    let fused = &sc.engines[0];
    let phases: Vec<&str> = fused.phases_ns.iter().map(|(n, _)| n.as_str()).collect();
    for want in ["create model", "predictions", "residuals", "mosum", "detect breaks"] {
        assert!(phases.contains(&want), "missing phase {want:?} in {phases:?}");
    }

    // canonical form: parse → serialise is a fixed point, and the
    // parsed value equals the original struct
    let canon = report.to_json_string();
    let back = BenchReport::from_json_str(&canon).unwrap();
    assert_eq!(back, report);
    assert_eq!(back.to_json_string(), canon);
}

#[test]
fn save_and_load_round_trip_through_a_file() {
    let report = bench::run_all(&BenchConfig {
        engines: vec![ENGINE_EMULATED.into()],
        ..tiny_cfg()
    })
    .unwrap();
    let path = std::env::temp_dir().join("bfast_bench_harness_roundtrip.json");
    report.save(&path).unwrap();
    let loaded = BenchReport::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, report);
}

#[test]
fn unknown_scenario_is_rejected_and_full_engine_set_runs() {
    let err = bench::run_all(&BenchConfig {
        scenarios: vec!["fig99".into()],
        ..tiny_cfg()
    })
    .unwrap_err();
    assert!(err.to_string().contains("no scenario"), "{err}");

    let err = bench::run_scenario(
        &bench::scenarios()[0],
        &BenchConfig { scale: 1e-9, warmup: 0, trials: 1, scenarios: vec![], engines: vec![] },
    )
    .map(|_| ())
    .err();
    assert!(err.is_none(), "full engine set must run");
}

fn fake_report(engine: &str, median_ns: u64, m: usize) -> BenchReport {
    BenchReport {
        version: SCHEMA_VERSION,
        fingerprint: Fingerprint {
            host_threads: 4,
            cargo_profile: "release".into(),
            git_rev: "deadbeef0000".into(),
            scale: 1.0,
            warmup: 1,
            trials: 5,
            source: SOURCE_HARNESS.into(),
        },
        scenarios: vec![ScenarioResult {
            scenario: "fig2".into(),
            about: "test".into(),
            m,
            n_total: 200,
            n_hist: 100,
            h: 50,
            k: 3,
            seed: 42,
            engines: vec![EngineResult {
                engine: engine.into(),
                trials_ns: vec![median_ns],
                median_ns,
                min_ns: median_ns,
                phases_ns: vec![],
            }],
        }],
    }
}

#[test]
fn diff_reports_speedups_and_regressions() {
    let base = fake_report(ENGINE_FUSED, 2_000_000, 20_000);
    let new = fake_report(ENGINE_FUSED, 1_000_000, 20_000);
    let d = bench::diff(&base, &new);
    assert_eq!(d.missing, Vec::<String>::new());
    assert_eq!(d.rows.len(), 1);
    let DiffRow { speedup, base_ns, new_ns, .. } = d.rows[0].clone();
    assert_eq!((base_ns, new_ns), (2_000_000, 1_000_000));
    assert!((speedup - 2.0).abs() < 1e-12);
    assert!(d.regressions(0.05).is_empty(), "a 2x speedup is not a regression");

    // the other direction trips the regression gate
    let d = bench::diff(&new, &base);
    assert_eq!(d.regressions(0.05).len(), 1);
    // ... unless tolerance covers it
    assert!(d.regressions(1.5).is_empty());
}

#[test]
fn diff_flags_unpaired_and_incomparable_results() {
    let base = fake_report(ENGINE_FUSED, 1_000, 20_000);
    // engine missing from the new report
    let new = fake_report(ENGINE_EMULATED, 1_000, 20_000);
    let d = bench::diff(&base, &new);
    assert!(d.rows.is_empty());
    assert!(!d.missing.is_empty());

    // same engine but different m: not comparable
    let new = fake_report(ENGINE_FUSED, 1_000, 40_000);
    let d = bench::diff(&base, &new);
    assert!(d.rows.is_empty());
    assert!(d.missing.iter().any(|s| s.contains("incomparable")), "{:?}", d.missing);
}

#[test]
fn tune_m_chunk_picks_a_candidate_and_measures_all() {
    let p = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 2.5).unwrap();
    let (best, rows) = bench::tune_m_chunk(&p, 64, &[16, 64], 1).unwrap();
    assert_eq!(rows.len(), 2);
    assert!(rows.iter().any(|&(mc, _)| mc == best));
    assert!(rows.iter().all(|&(_, ns)| ns > 0));
}

/// The committed trajectory files must stay readable by the current
/// schema — this is the contract `bench diff BENCH_PR6_BASELINE.json
/// BENCH_PR6.json` and future PRs depend on. (Test cwd is `rust/`.)
#[test]
fn committed_trajectory_files_are_schema_valid() {
    for path in ["../BENCH_PR6_BASELINE.json", "../BENCH_PR6.json"] {
        let report = BenchReport::load(path).unwrap();
        assert_eq!(report.version, SCHEMA_VERSION, "{path}");
        assert!(!report.scenarios.is_empty(), "{path}");
        // measured outside the harness: provenance must say so
        assert_eq!(report.fingerprint.source, "kernel-replica-c", "{path}");
        let canon = report.to_json_string();
        assert_eq!(BenchReport::from_json_str(&canon).unwrap(), report, "{path}");
    }
    // and the pair must demonstrate the PR's fig2 fused-CPU speedup
    let base = BenchReport::load("../BENCH_PR6_BASELINE.json").unwrap();
    let new = BenchReport::load("../BENCH_PR6.json").unwrap();
    let d = bench::diff(&base, &new);
    let fused = d
        .rows
        .iter()
        .find(|r| r.scenario == "fig2" && r.engine == ENGINE_FUSED)
        .expect("fig2 fused-cpu pair present");
    assert!(
        fused.speedup >= 1.3,
        "pinned trajectory: fig2 fused-cpu must show >= 1.3x (got {:.2}x)",
        fused.speedup
    );
}
