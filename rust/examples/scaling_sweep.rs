//! End-to-end validation driver (Fig. 2 analogue): run all four
//! implementations over increasing m on the paper's synthetic
//! workload, print runtimes + speedups over the naive (R-analogue)
//! baseline, verify every implementation agrees with the reference,
//! and save the table to `results/`.
//!
//! m is scaled for a laptop run by default; set `SWEEP_M_MAX` /
//! `SWEEP_POINTS` to go bigger (the paper sweeps 100k..1M).
//!
//! ```sh
//! make artifacts && cargo run --release --example scaling_sweep
//! ```

use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::pixel::{DirectBfast, NaiveBfast};
use bfast::report::Table;
use bfast::synth::ArtificialDataset;
use std::time::Instant;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> bfast::error::Result<()> {
    let params = BfastParams::paper_synthetic();
    let m_max = env_usize("SWEEP_M_MAX", 100_000);
    let points = env_usize("SWEEP_POINTS", 5);
    // naive is O(100x) slower; cap its workload like the paper caps R's
    let naive_cap = env_usize("SWEEP_NAIVE_CAP", 4_000);

    let runner = BfastRunner::auto("artifacts", RunnerConfig::default())?;
    println!("device: {}", runner.platform());

    let mut table = Table::new(
        "fig2: runtime vs m (seconds)",
        &["m", "naive_R", "direct_Py", "cpu_multi", "device", "speedup_cpu", "speedup_dev"],
    );

    for i in 1..=points {
        let m = m_max * i / points;
        let data = ArtificialDataset::new(params.clone(), m, 42).generate();
        let stack = &data.stack;

        // naive (BFAST(R) analogue) on a capped subset, extrapolated
        let naive_m = m.min(naive_cap);
        let sub = stack.slice_pixels(0, naive_m);
        let t0 = Instant::now();
        let naive_map = NaiveBfast::new(params.clone()).run(&sub)?;
        let naive_s = t0.elapsed().as_secs_f64() * (m as f64 / naive_m as f64);

        // direct (BFAST(Python) analogue)
        let direct = DirectBfast::new(params.clone(), &stack.time_axis)?;
        let t0 = Instant::now();
        let direct_map = direct.run(stack)?;
        let direct_s = t0.elapsed().as_secs_f64();

        // fused multi-core (BFAST(CPU))
        let cpu = FusedCpuBfast::new(params.clone(), &stack.time_axis)?;
        let t0 = Instant::now();
        let (cpu_map, _) = cpu.run(stack)?;
        let cpu_s = t0.elapsed().as_secs_f64();

        // device (BFAST(GPU) analogue)
        let res = runner.run(stack, &params)?;
        let dev_s = res.wall.as_secs_f64();

        // cross-implementation agreement (the correctness part of the
        // end-to-end validation)
        bfast::ensure!(
            direct_map.breaks == cpu_map.breaks,
            "direct vs cpu disagreement at m={m}"
        );
        bfast::ensure!(
            naive_map.breaks[..] == direct_map.breaks[..naive_m],
            "naive vs direct disagreement at m={m}"
        );
        let agree = res
            .map
            .breaks
            .iter()
            .zip(&cpu_map.breaks)
            .filter(|(a, b)| a == b)
            .count() as f64
            / m as f64;
        bfast::ensure!(agree > 0.999, "device vs cpu agreement {agree} at m={m}");

        println!(
            "m={m:>8}: naive*={naive_s:>8.2}s direct={direct_s:>8.2}s cpu={cpu_s:>7.3}s \
             device={dev_s:>7.3}s (agree {:.4})",
            agree
        );
        table.row(vec![
            m.to_string(),
            Table::num(naive_s),
            Table::num(direct_s),
            Table::num(cpu_s),
            Table::num(dev_s),
            Table::num(naive_s / cpu_s),
            Table::num(naive_s / dev_s),
        ]);
    }

    print!("{}", table.to_console());
    let path = table.save("results", "fig2_scaling")?;
    println!("saved {}", path.display());
    println!("(naive_R column extrapolated beyond {naive_cap} px, as the paper does for R)");
    Ok(())
}
