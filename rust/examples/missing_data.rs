//! Missing-data handling (paper footnote 2 + the "variants capable of
//! dealing with many missing values" future-work item): run the Chile
//! scene with cloud-masked (NaN) observations through the coordinator,
//! whose staging workers gap-fill each chunk, and compare against the
//! same scene without clouds.
//!
//! ```sh
//! make artifacts && cargo run --release --example missing_data
//! ```

use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::fill;
use bfast::synth::ChileScene;

fn main() -> bfast::error::Result<()> {
    let clean_scene = ChileScene::scaled(96, 72, 11);
    let cloudy_scene = ChileScene { cloud_rate: 0.08, ..clean_scene.clone() };
    let params = clean_scene.params();

    let (clean, _) = clean_scene.generate();
    let (mut cloudy, _) = cloudy_scene.generate();
    let nan_count = cloudy.data().iter().filter(|v| v.is_nan()).count();
    println!(
        "scene {}x{}: {} observations, {} cloud-masked ({:.1}%)",
        clean_scene.width,
        clean_scene.height,
        cloudy.data().len(),
        nan_count,
        100.0 * nan_count as f64 / cloudy.data().len() as f64
    );

    let runner = BfastRunner::auto("artifacts", RunnerConfig::default())?;

    // Coordinator path: staging-side gap filling (fill_missing = true).
    let res_clean = runner.run(&clean, &params)?;
    let res_cloudy = runner.run(&cloudy, &params)?;
    println!(
        "breaks: clean {:.2}%  cloudy(staging-filled) {:.2}%",
        100.0 * res_clean.map.break_fraction(),
        100.0 * res_cloudy.map.break_fraction()
    );

    // Same data pre-filled on the host — must agree with staging fill.
    let stats = fill::fill_stack(&mut cloudy, bfast::threadpool::default_threads());
    println!(
        "host fill: {} gap pixels, {} values, longest gap {}",
        stats.pixels_with_gaps, stats.missing_values, stats.longest_gap
    );
    let res_prefilled = runner.run(&cloudy, &params)?;
    bfast::ensure!(
        res_prefilled.map.breaks == res_cloudy.map.breaks,
        "staging-side fill must equal host-side fill"
    );

    // Detection should survive moderate cloud cover.
    let mut agree = 0usize;
    for (a, b) in res_clean.map.breaks.iter().zip(&res_cloudy.map.breaks) {
        agree += (a == b) as usize;
    }
    let rate = agree as f64 / res_clean.len() as f64;
    println!("clean vs cloudy agreement: {:.2}%", 100.0 * rate);
    bfast::ensure!(rate > 0.9, "cloud gaps degraded detection too much");
    println!("missing_data OK");
    Ok(())
}
