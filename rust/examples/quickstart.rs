//! Quickstart: generate a small artificial scene, describe the
//! analysis as one `bfast::api::AnalysisRequest` (the same object a
//! server submit posts), execute it through the AOT device pipeline,
//! cross-check against the multi-core CPU implementation, and inspect
//! one broken pixel.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use bfast::api::{AnalysisRequest, EngineSpec, JobHandle, ParamSpec, SceneSource};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::synth::ArtificialDataset;

fn main() -> bfast::error::Result<()> {
    // The paper's synthetic benchmark setting (§4.2), small m.
    let params = BfastParams::paper_synthetic();
    println!(
        "params: N={} n={} h={} k={} f={} alpha={} -> lambda={:.3}",
        params.n_total, params.n_hist, params.h, params.k, params.freq, params.alpha,
        params.lambda
    );

    let data = ArtificialDataset::new(params.clone(), 20_000, 42)
        .with_noise(0.01, 0.1)
        .generate();
    println!(
        "generated {} pixels x {} timesteps ({} with injected breaks)",
        data.stack.n_pixels(),
        data.stack.n_times(),
        data.truth.iter().filter(|&&t| t).count()
    );

    // --- device pipeline, through the front door ------------------------
    // the request is self-describing: `req.to_json_string()` is exactly
    // what `bfast client submit` would POST to a serve instance
    let mut req = AnalysisRequest::new(SceneSource::Inline(data.stack.clone()));
    req.params = ParamSpec::from_params(&params);
    req.engine = EngineSpec::Device { artifacts: "artifacts".into(), artifact: None };
    let res = req.execute(&JobHandle::new())?;
    println!("device: {}", res.engine);
    let (tpr, fpr) = data.score(&res.map.breaks);
    println!(
        "device: {} breaks / {} px in {:.3}s ({} chunks, artifact {})  TPR={:.3} FPR={:.3}",
        res.map.break_count(),
        res.map.len(),
        res.wall.as_secs_f64(),
        res.chunks,
        res.artifact,
        tpr,
        fpr
    );
    if let Some(phases) = &res.phases {
        print!("{}", phases.table("device phases"));
    }

    // --- multi-core CPU cross-check -------------------------------------
    let cpu = FusedCpuBfast::new(params.clone(), &data.stack.time_axis)?;
    let t0 = std::time::Instant::now();
    let (cpu_map, cpu_phases) = cpu.run(&data.stack)?;
    println!("cpu: {} breaks in {:.3}s", cpu_map.break_count(), t0.elapsed().as_secs_f64());
    print!("{}", cpu_phases.table("cpu phases"));

    let agree = res
        .map
        .breaks
        .iter()
        .zip(&cpu_map.breaks)
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "device/cpu agreement: {agree}/{} ({:.4}%)",
        res.map.len(),
        100.0 * agree as f64 / res.map.len() as f64
    );
    bfast::ensure!(
        agree as f64 / res.map.len() as f64 > 0.999,
        "device and CPU implementations disagree"
    );

    // --- per-pixel inspection (the paper's post-hoc workflow) -----------
    if let Some(px) = res.map.breaks.iter().position(|&b| b != 0) {
        let runner = BfastRunner::emulated(RunnerConfig::default())?;
        let detail = runner.inspect_pixel(&data.stack, &params, px)?;
        println!(
            "pixel {px}: first crossing at monitor step {} (t={}), momax={:.2}",
            detail.scan.first,
            params.n_hist as i32 + 1 + detail.scan.first,
            detail.scan.momax
        );
    }
    println!("quickstart OK");
    Ok(())
}
