//! §4.3 analogue: large-scale break detection on the (simulated)
//! Chile Landsat scene — irregular day-of-year time axis, chunked
//! streaming, Fig. 7 snapshots and the Fig. 9 max|MOSUM| heatmap.
//!
//! ```sh
//! make artifacts && cargo run --release --example chile_monitor
//! ```
//! Scale the scene with CHILE_W / CHILE_H (paper: 2400 x 1851).

use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::raster::pgm;
use bfast::synth::ChileScene;
use std::time::Instant;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> bfast::error::Result<()> {
    let scene = ChileScene::scaled(env_usize("CHILE_W", 240), env_usize("CHILE_H", 186), 2017);
    let params = scene.params();
    println!(
        "chile scene {}x{} ({} px), N={} irregular acquisitions over {:.1} years",
        scene.width,
        scene.height,
        scene.width * scene.height,
        scene.n_times,
        6424.0 / 365.0
    );
    println!(
        "params: n={} h={} k={} f={} alpha={} -> lambda={:.3} (paper: 2.39)",
        params.n_hist, params.h, params.k, params.freq, params.alpha, params.lambda
    );

    let (stack, truth) = scene.generate();
    std::fs::create_dir_all("results")?;

    // Fig. 7 analogue: snapshot layers as PGM heatmaps
    for (tag, ti) in [("a_first", 0usize), ("e_160", 159), ("f_200", 199), ("h_last", 287)] {
        let path = format!("results/chile_snapshot_{tag}.pgm");
        let layer = stack.layer(ti.min(stack.n_times() - 1));
        pgm::write_pgm(&path, layer, scene.width, scene.height, 0.0, 0.8)?;
    }
    println!("wrote results/chile_snapshot_*.pgm (Fig. 7 analogue)");

    // Device run over the full scene
    let runner = BfastRunner::auto("artifacts", RunnerConfig::default())?;
    let res = runner.run(&stack, &params)?;
    println!(
        "device: {:.3}s for {} px in {} chunks — {:.2}% breaks (paper: >99%)",
        res.wall.as_secs_f64(),
        res.len(),
        res.chunks,
        100.0 * res.map.break_fraction()
    );
    print!("{}", res.phases.table("device phases"));

    // CPU comparison (the paper's 32.8 s vs 3.9 s shape)
    let cpu = FusedCpuBfast::new(params.clone(), &stack.time_axis)?;
    let t0 = Instant::now();
    let (cpu_map, _) = cpu.run(&stack)?;
    let cpu_s = t0.elapsed().as_secs_f64();
    println!(
        "cpu:    {:.3}s — {:.2}% breaks; device speedup {:.1}x",
        cpu_s,
        100.0 * cpu_map.break_fraction(),
        cpu_s / res.wall.as_secs_f64()
    );

    // Fig. 9: heatmap of max |MOSUM|
    let momax_path = "results/chile_momax.pgm";
    let (lo, hi) = pgm::write_pgm_autoscale(momax_path, &res.map.momax, scene.width, scene.height)?;
    println!("wrote results/chile_momax.pgm (Fig. 9 analogue, scale {lo:.1}..{hi:.1})");

    // forest blocks must show larger MOSUM magnitudes than desert
    let (mut forest_sum, mut forest_n) = (0.0f64, 0usize);
    let (mut desert_sum, mut desert_n) = (0.0f64, 0usize);
    for (px, &f) in truth.is_forest.iter().enumerate() {
        if f {
            forest_sum += res.map.momax[px] as f64;
            forest_n += 1;
        } else {
            desert_sum += res.map.momax[px] as f64;
            desert_n += 1;
        }
    }
    let fm = forest_sum / forest_n as f64;
    let dm = desert_sum / desert_n as f64;
    println!("mean max|MOSUM|: forest {fm:.1}, desert {dm:.1} (paper: forest ≫ desert)");
    bfast::ensure!(fm > dm, "forest magnitudes should dominate");
    bfast::ensure!(res.map.break_fraction() > 0.95, "expect near-total break coverage");
    println!("chile_monitor OK");
    Ok(())
}
