//! Fig. 5 — influence of the number of harmonic terms k (1..5) on the
//! per-phase runtimes of both implementations. The paper finds no
//! significant impact for realistic k; only the model-creation phase
//! is even theoretically affected.

use bfast::bench_support::{banner, scaled_m};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::report::Table;
use bfast::synth::ArtificialDataset;

fn main() -> bfast::error::Result<()> {
    banner("fig5", "influence of k on the phases");
    let m = scaled_m(50_000);
    let mut cpu_table = Table::new(
        "fig5a: CPU phase seconds vs k",
        &["k", "create model", "predictions", "residuals", "mosum", "detect breaks", "total"],
    );
    let mut dev_table = Table::new(
        "fig5b: device phase seconds vs k",
        &["k", "transfer", "create model", "predictions", "mosum", "detect breaks", "total"],
    );

    let mut runner = BfastRunner::auto(
        "artifacts",
        RunnerConfig { phased: true, ..Default::default() },
    )?;
    println!("device backend: {}", runner.platform());
    for k in 1..=5usize {
        let params = BfastParams::new(200, 100, 50, k, 23.0, 0.05)?;
        let data = ArtificialDataset::new(params.clone(), m, 42).generate();

        let cpu = FusedCpuBfast::new(params.clone(), &data.stack.time_axis)?;
        let (_, ph) = cpu.run(&data.stack)?;
        let g = |n: &str| Table::num(ph.get(n).unwrap_or_default().as_secs_f64());
        cpu_table.row(vec![
            k.to_string(),
            g("create model"),
            g("predictions"),
            g("residuals"),
            g("mosum"),
            g("detect breaks"),
            Table::num(ph.total().as_secs_f64()),
        ]);

        runner.cfg.artifact = Some(if k == 3 { "default".into() } else { format!("k{k}") });
        let _ = runner.run(&data.stack, &params)?; // compile warmup per k
        let res = runner.run(&data.stack, &params)?;
        let g = |n: &str| Table::num(res.phases.get(n).unwrap_or_default().as_secs_f64());
        dev_table.row(vec![
            k.to_string(),
            g("transfer"),
            g("create model"),
            g("predictions"),
            g("mosum"),
            g("detect breaks"),
            Table::num(res.phases.total().as_secs_f64()),
        ]);
        println!(
            "k={k}: cpu {:.3}s, device {:.3}s",
            ph.total().as_secs_f64(),
            res.phases.total().as_secs_f64()
        );
    }
    print!("{}", cpu_table.to_console());
    print!("{}", dev_table.to_console());
    cpu_table.save("results", "fig5a_cpu_k")?;
    dev_table.save("results", "fig5b_dev_k")?;
    println!("expected shape (paper): no phase significantly impacted by k");
    Ok(())
}
