//! Fig. 4 — per-phase runtimes of the CPU and device implementations
//! as m grows: every phase should scale ~linearly in m, preserving the
//! Fig. 3 shape at each size.

use bfast::bench_support::{banner, scaled_m};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::report::Table;
use bfast::synth::ArtificialDataset;

fn main() -> bfast::error::Result<()> {
    banner("fig4", "phases vs m");
    let params = BfastParams::paper_synthetic();
    let mut cpu_table = Table::new(
        "fig4a: CPU phase seconds vs m",
        &["m", "create model", "predictions", "residuals", "mosum", "detect breaks"],
    );
    let mut dev_table = Table::new(
        "fig4b: device phase seconds vs m",
        &["m", "transfer", "create model", "predictions", "mosum", "detect breaks", "readback"],
    );

    let runner = BfastRunner::auto(
        "artifacts",
        RunnerConfig { phased: true, ..Default::default() },
    )?;
    println!("device backend: {}", runner.platform());
    let base = scaled_m(20_000);
    for step in 1..=5usize {
        let m = base * step;
        let data = ArtificialDataset::new(params.clone(), m, 42).generate();

        let cpu = FusedCpuBfast::new(params.clone(), &data.stack.time_axis)?;
        let (_, ph) = cpu.run(&data.stack)?;
        cpu_table.row(vec![
            m.to_string(),
            Table::num(ph.get("create model").unwrap_or_default().as_secs_f64()),
            Table::num(ph.get("predictions").unwrap_or_default().as_secs_f64()),
            Table::num(ph.get("residuals").unwrap_or_default().as_secs_f64()),
            Table::num(ph.get("mosum").unwrap_or_default().as_secs_f64()),
            Table::num(ph.get("detect breaks").unwrap_or_default().as_secs_f64()),
        ]);

        if step == 1 {
            let _ = runner.run(&data.stack, &params)?; // compile warmup
        }
        let res = runner.run(&data.stack, &params)?;
        let g = |n: &str| Table::num(res.phases.get(n).unwrap_or_default().as_secs_f64());
        dev_table.row(vec![
            m.to_string(),
            g("transfer"),
            g("create model"),
            g("predictions"),
            g("mosum"),
            g("detect breaks"),
            g("readback"),
        ]);
        println!("m={m:>8}: cpu total {:.3}s, device total {:.3}s",
            ph.total().as_secs_f64(), res.phases.total().as_secs_f64());
    }
    print!("{}", cpu_table.to_console());
    print!("{}", dev_table.to_console());
    cpu_table.save("results", "fig4a_cpu_phases_vs_m")?;
    dev_table.save("results", "fig4b_dev_phases_vs_m")?;
    println!("expected shape (paper): all phases grow ~linearly; device transfer dominates at every m");
    Ok(())
}
