//! Fig. 8 — Chile scene: runtime of CPU and device implementations on
//! 1/6 .. 6/6 of the scene (the paper splits the 2400×1851 scene into
//! six equal parts). Runtime must grow linearly; the device path must
//! beat the fused CPU path (paper: 3.9 s vs 32.8 s at full scale).
//! Also checks the §4.3 claims: >99 % of pixels break.

use bfast::bench_support::{banner, bench_scale};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::report::Table;
use bfast::synth::ChileScene;
use std::time::Instant;

fn main() -> bfast::error::Result<()> {
    banner("fig8", "Chile scene, chunked runtimes");
    let scale = bench_scale().sqrt();
    let scene = ChileScene::scaled(
        ((240.0 * scale) as usize).max(32),
        ((186.0 * scale) as usize).max(32),
        2017,
    );
    let params = scene.params();
    let (stack, _) = scene.generate();
    let m = stack.n_pixels();
    println!("scene {}x{} = {m} px, N={}", scene.width, scene.height, scene.n_times);

    let cpu = FusedCpuBfast::new(params.clone(), &stack.time_axis)?;
    let runner = BfastRunner::auto(
        "artifacts",
        RunnerConfig { artifact: Some("chile".into()), ..Default::default() },
    )?;
    println!("device backend: {}", runner.platform());
    // compile warmup on a small slice
    let warm = stack.slice_pixels(0, (m / 6).max(1));
    let _ = runner.run(&warm, &params)?;

    let mut table = Table::new(
        "fig8: seconds vs scene fraction",
        &["parts", "pixels", "cpu_s", "device_s", "speedup"],
    );
    let mut dev_full = 0.0;
    let mut cpu_full = 0.0;
    for parts in 1..=6usize {
        let end = m * parts / 6;
        let sub = stack.slice_pixels(0, end);
        let t0 = Instant::now();
        let (cpu_map, _) = cpu.run(&sub)?;
        let cpu_s = t0.elapsed().as_secs_f64();
        let res = runner.run(&sub, &params)?;
        let dev_s = res.wall.as_secs_f64();
        println!(
            "parts={parts}: {end:>8} px  cpu={cpu_s:>7.3}s  device={dev_s:>7.3}s  \
             breaks cpu {:.2}% dev {:.2}%",
            100.0 * cpu_map.break_fraction(),
            100.0 * res.map.break_fraction()
        );
        table.row(vec![
            parts.to_string(),
            end.to_string(),
            Table::num(cpu_s),
            Table::num(dev_s),
            Table::num(cpu_s / dev_s),
        ]);
        if parts == 6 {
            dev_full = dev_s;
            cpu_full = cpu_s;
            bfast::ensure!(
                res.map.break_fraction() > 0.95,
                "expected near-total break coverage (paper: >99%)"
            );
        }
    }
    print!("{}", table.to_console());
    table.save("results", "fig8_chile")?;
    println!(
        "full scene: cpu {cpu_full:.3}s vs device {dev_full:.3}s (paper shape: 32.8s vs 3.9s); \
         linear growth expected"
    );
    Ok(())
}
