//! Fig. 2 — runtime of the four implementations vs the number of time
//! series m, plus speedups over the naive (R-analogue) baseline.
//!
//! Paper setting: N=200, n=100, f=23, h=50, k=3, alpha=0.05;
//! m = 100k..1M. Default workload is laptop-sized; crank
//! BFAST_BENCH_SCALE (e.g. 10) to approach the paper's sizes.

use bfast::bench_support::{banner, scaled_m, Bench};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::pixel::{DirectBfast, NaiveBfast};
use bfast::report::Table;
use bfast::synth::ArtificialDataset;

fn main() -> bfast::error::Result<()> {
    banner("fig2", "runtime of BFAST(R/Python/CPU/GPU) analogues vs m");
    let params = BfastParams::paper_synthetic();
    let bench = Bench::quick().from_env();
    let naive_cap = 2_000usize;

    let runner = BfastRunner::auto("artifacts", RunnerConfig::default())?;
    println!("device backend: {}", runner.platform());
    let mut table = Table::new(
        "fig2: seconds per implementation (naive extrapolated past cap)",
        &["m", "naive_R", "direct_Py", "cpu_multi", "device", "su_direct", "su_cpu", "su_device"],
    );

    let base = scaled_m(10_000);
    for step in 1..=5usize {
        let m = base * step;
        let data = ArtificialDataset::new(params.clone(), m, 42).generate();
        let stack = &data.stack;

        let naive_m = m.min(naive_cap);
        let sub = stack.slice_pixels(0, naive_m);
        let naive = NaiveBfast::new(params.clone());
        let naive_s = bench.run(|| naive.run(&sub).unwrap()).secs() * (m as f64 / naive_m as f64);

        let direct = DirectBfast::new(params.clone(), &stack.time_axis)?;
        let direct_s = bench.run(|| direct.run(stack).unwrap()).secs();

        let cpu = FusedCpuBfast::new(params.clone(), &stack.time_axis)?;
        let cpu_s = bench.run(|| cpu.run(stack).unwrap()).secs();

        let dev_s = bench.run(|| runner.run(stack, &params).unwrap()).secs();

        println!(
            "m={m:>8}  naive*={naive_s:>9.3}s  direct={direct_s:>8.3}s  cpu={cpu_s:>7.3}s  \
             device={dev_s:>7.3}s  | speedups over naive: direct {:.0}x cpu {:.0}x device {:.0}x",
            naive_s / direct_s,
            naive_s / cpu_s,
            naive_s / dev_s
        );
        table.row(vec![
            m.to_string(),
            Table::num(naive_s),
            Table::num(direct_s),
            Table::num(cpu_s),
            Table::num(dev_s),
            Table::num(naive_s / direct_s),
            Table::num(naive_s / cpu_s),
            Table::num(naive_s / dev_s),
        ]);
    }
    print!("{}", table.to_console());
    table.save("results", "fig2_impls")?;
    println!("expected shape (paper): naive >> direct >> cpu > device, ratios ~constant in m");
    Ok(())
}
