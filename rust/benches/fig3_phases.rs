//! Fig. 3 — per-phase runtime breakdown of (a) the fused multi-core
//! CPU implementation and (b) the device pipeline at a fixed m.
//!
//! The device side uses the phase-instrumented executables (fit /
//! predict / mosum / detect as separate HLO modules) plus the measured
//! host→device transfer — the paper's five GPU phases. A fused-path
//! row is appended to show what the production configuration does to
//! the same work.

use bfast::bench_support::{banner, scaled_m};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::report::Table;
use bfast::synth::ArtificialDataset;

fn main() -> bfast::error::Result<()> {
    banner("fig3", "phase breakdown, CPU vs device");
    let params = BfastParams::paper_synthetic();
    let m = scaled_m(100_000);
    let data = ArtificialDataset::new(params.clone(), m, 42).generate();

    // (a) CPU phases
    let cpu = FusedCpuBfast::new(params.clone(), &data.stack.time_axis)?;
    let (_, cpu_phases) = cpu.run(&data.stack)?; // warmup
    let (_, cpu_phases2) = cpu.run(&data.stack)?;
    let _ = cpu_phases;
    print!("{}", cpu_phases2.table(&format!("(a) BFAST(CPU) phases, m={m}")));

    // (b) device phases (instrumented pipeline)
    let runner = BfastRunner::auto(
        "artifacts",
        RunnerConfig { phased: true, ..Default::default() },
    )?;
    println!("device backend: {}", runner.platform());
    let _ = runner.run(&data.stack, &params)?; // warmup (compiles)
    let res = runner.run(&data.stack, &params)?;
    print!("{}", res.phases.table(&format!("(b) BFAST(device) phases, m={m}")));

    // fused-path reference (the production configuration)
    let fused_runner = BfastRunner::auto("artifacts", RunnerConfig::default())?;
    let _ = fused_runner.run(&data.stack, &params)?;
    let fres = fused_runner.run(&data.stack, &params)?;
    print!("{}", fres.phases.table("(b') device fused path, same work"));

    let mut t = Table::new("fig3: phase seconds", &["impl", "phase", "seconds"]);
    for (n, d) in cpu_phases2.iter() {
        t.row(vec!["cpu".into(), n.into(), Table::num(d.as_secs_f64())]);
    }
    for (n, d) in res.phases.iter() {
        t.row(vec!["device".into(), n.into(), Table::num(d.as_secs_f64())]);
    }
    for (n, d) in fres.phases.iter() {
        t.row(vec!["device-fused".into(), n.into(), Table::num(d.as_secs_f64())]);
    }
    t.save("results", "fig3_phases")?;
    println!(
        "expected shape (paper): CPU time spread across all phases; device dominated by transfer"
    );
    Ok(())
}
