//! Ablations for the design choices DESIGN.md calls out:
//!
//! 1. Pallas kernel vs plain-XLA fusion for the MOSUM stage (same math,
//!    with/without the explicit BlockSpec schedule).
//! 2. Coordinator queue depth (backpressure window) and staging thread
//!    count — the transfer/compute overlap knobs.
//! 3. Fused single-executable pipeline vs phased per-stage executables
//!    (the cost of intermediate round-trips).

use bfast::bench_support::{banner, scaled_m, Bench};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::params::BfastParams;
use bfast::report::Table;
use bfast::synth::ArtificialDataset;

fn main() -> bfast::error::Result<()> {
    banner("ablation", "pallas-vs-xla, queue depth, fused-vs-phased");
    let params = BfastParams::paper_synthetic();
    let m = scaled_m(100_000);
    let data = ArtificialDataset::new(params.clone(), m, 42).generate();
    let bench = Bench::quick().from_env();
    let mut table = Table::new("ablations (seconds, steady-state)", &["config", "seconds"]);

    // 1. pallas vs xla artifact — only meaningful on the real device
    // backend; the emulated fallback would measure the same code twice
    // and record an ablation that never happened.
    let probe = BfastRunner::auto("artifacts", RunnerConfig::default())?;
    if probe.platform().contains("emulated") {
        println!("kernel ablation SKIPPED: emulated backend (needs pjrt + artifacts)");
    } else {
        for name in ["default", "default_xla"] {
            let runner = BfastRunner::auto(
                "artifacts",
                RunnerConfig { artifact: Some(name.into()), ..Default::default() },
            )?;
            let _ = runner.run(&data.stack, &params)?; // compile
            let s = bench.run(|| runner.run(&data.stack, &params).unwrap()).secs();
            println!("kernel={name:<12} {s:.3}s");
            table.row(vec![format!("kernel:{name}"), Table::num(s)]);
        }
    }

    // 2. queue depth × staging threads
    for (depth, threads) in [(1usize, 1usize), (2, 1), (4, 1), (2, 2)] {
        let runner = BfastRunner::auto(
            "artifacts",
            RunnerConfig {
                artifact: Some("default".into()),
                queue_depth: depth,
                staging_threads: threads,
                ..Default::default()
            },
        )?;
        let _ = runner.run(&data.stack, &params)?;
        let s = bench.run(|| runner.run(&data.stack, &params).unwrap()).secs();
        println!("queue_depth={depth} staging={threads}: {s:.3}s");
        table.row(vec![format!("queue{depth}-stage{threads}"), Table::num(s)]);
    }

    // 3. fused vs phased
    for phased in [false, true] {
        let runner = BfastRunner::auto(
            "artifacts",
            RunnerConfig {
                artifact: Some("default".into()),
                phased,
                ..Default::default()
            },
        )?;
        let _ = runner.run(&data.stack, &params)?;
        let s = bench.run(|| runner.run(&data.stack, &params).unwrap()).secs();
        let label = if phased { "phased" } else { "fused" };
        println!("pipeline={label}: {s:.3}s");
        table.row(vec![format!("pipeline:{label}"), Table::num(s)]);
    }

    print!("{}", table.to_console());
    table.save("results", "ablations")?;
    Ok(())
}
