//! Fig. 6 — influence of the MOSUM bandwidth h (25 / 50 / 100) on the
//! MOSUM phase and the total runtime. Only the *first* window sum
//! depends on h (rolling update afterwards), so the paper finds no
//! impact — our rolling-update CPU phase and cumsum-based kernel
//! preserve that property.

use bfast::bench_support::{banner, scaled_m};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::report::Table;
use bfast::synth::ArtificialDataset;

fn main() -> bfast::error::Result<()> {
    banner("fig6", "influence of h on MOSUM phase + total");
    let m = scaled_m(50_000);
    let mut table = Table::new(
        "fig6: seconds vs h",
        &["h", "cpu_mosum", "cpu_total", "dev_mosum", "dev_total"],
    );
    let mut runner = BfastRunner::auto(
        "artifacts",
        RunnerConfig { phased: true, ..Default::default() },
    )?;
    println!("device backend: {}", runner.platform());
    for h in [25usize, 50, 100] {
        let params = BfastParams::new(200, 100, h, 3, 23.0, 0.05)?;
        let data = ArtificialDataset::new(params.clone(), m, 42).generate();

        let cpu = FusedCpuBfast::new(params.clone(), &data.stack.time_axis)?;
        let (_, ph) = cpu.run(&data.stack)?;

        runner.cfg.artifact = Some(if h == 50 { "default".into() } else { format!("h{h}") });
        let _ = runner.run(&data.stack, &params)?; // compile warmup
        let res = runner.run(&data.stack, &params)?;

        let cpu_mosum = ph.get("mosum").unwrap_or_default().as_secs_f64();
        let dev_mosum = res.phases.get("mosum").unwrap_or_default().as_secs_f64();
        println!(
            "h={h:>3}: cpu mosum {cpu_mosum:.3}s / total {:.3}s | device mosum {dev_mosum:.3}s / total {:.3}s",
            ph.total().as_secs_f64(),
            res.phases.total().as_secs_f64()
        );
        table.row(vec![
            h.to_string(),
            Table::num(cpu_mosum),
            Table::num(ph.total().as_secs_f64()),
            Table::num(dev_mosum),
            Table::num(res.phases.total().as_secs_f64()),
        ]);
    }
    print!("{}", table.to_console());
    table.save("results", "fig6_h")?;
    println!("expected shape (paper): h has no impact on either implementation");
    Ok(())
}
